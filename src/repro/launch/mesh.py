"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — required because the dry-run must
set XLA_FLAGS before jax initializes.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(data: int, model: int, pods: int = 1):
    """Arbitrary mesh for tests/examples (CPU fake devices)."""
    if pods > 1:
        return jax.make_mesh((pods, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
