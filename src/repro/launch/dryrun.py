import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape) cell on the
production meshes (16x16 single-pod, 2x16x16 multi-pod), print
memory_analysis / cost_analysis, and record roofline inputs (FLOPs, bytes,
collective bytes parsed from the optimized HLO) as JSON under
experiments/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape decode_32k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod] [--impl X]
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, get_arch, list_archs
from repro.core.paged_kv import make_layout
from repro.launch.mesh import make_production_mesh
from repro.models.model_zoo import input_specs
from repro.models.transformer import init_cache, init_params
from repro.runtime.optimizer import default_opt_for
from repro.runtime.train_state import init_train_state, make_train_step
from repro.serving.decode import cache_shardings, make_prefill_step, make_serve_step
from repro.sharding.params import params_shardings, state_shardings
from repro.sharding.policy import mesh_axis_size, policy_for
from repro.utils.hlo import collective_bytes, collective_counts, convert_bytes

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _sds_with_shardings(tree, shardings):
    """abstract pytree + sharding pytree -> ShapeDtypeStructs w/ shardings."""
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        tree, shardings)


def _abstract(fn, *args, **kw):
    return jax.eval_shape(fn, *args, **kw)


def build_cell(cfg, shape, mesh, impl=None):
    """Returns (step_fn, abstract_args) for one (arch, shape, mesh) cell."""
    pol = policy_for(cfg, mesh, shape)
    if impl:
        cfg = cfg.replace(attention_impl=impl)
    n_workers = mesh_axis_size(mesh, "model")
    key = jax.random.PRNGKey(0)

    params_a = _abstract(lambda: init_params(cfg, key))
    p_sh = params_shardings(pol, params_a)
    batch_a = input_specs(cfg, shape)
    bspec = pol.batch_spec
    from jax.sharding import PartitionSpec as P

    def batch_shard(a):
        spec = P(*( (bspec,) + (None,) * (len(a.shape) - 1) ))
        return pol.named(spec)

    batch = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype,
                                       sharding=batch_shard(a)), batch_a)

    if shape.mode == "train":
        oc = default_opt_for(cfg)
        state_a = _abstract(lambda: init_train_state(cfg, params_a, oc))
        s_sh = state_shardings(pol, state_a)
        state = _sds_with_shardings(state_a, s_sh)
        step = make_train_step(cfg, pol, oc)
        return step, (state, batch), pol

    if shape.mode == "prefill":
        params = _sds_with_shardings(params_a, p_sh)
        step = make_prefill_step(
            cfg, pol, make_layout(cfg, shape.seq_len, n_workers),
            length=shape.seq_len)
        return step, (params, batch), pol

    # decode: cache of seq_len context + one new token
    layout = make_layout(cfg, shape.seq_len, n_workers)
    cache_a = _abstract(lambda: init_cache(
        cfg, shape.global_batch, shape.seq_len, n_workers,
        enc_len=cfg.frontend_len))
    c_sh = cache_shardings(cfg, pol, layout)
    cache = _sds_with_shardings(cache_a, c_sh)
    params = _sds_with_shardings(params_a, p_sh)
    step = make_serve_step(cfg, pol, layout)
    # donate the cache: steady-state decode must be allocation-free, and an
    # undonated cache costs a full KV copy per step (§Perf iteration 2)
    step.donate_argnums = (1,)
    return step, (params, cache, batch["token"]), pol


def _cost_of(cfg, shape, mesh, impl):
    """Lower+compile one configuration and return (flops, bytes, coll)."""
    step, args, _ = build_cell(cfg, shape, mesh, impl=impl)
    donate = getattr(step, "donate_argnums", ())
    compiled = jax.jit(step, donate_argnums=donate).lower(*args).compile()
    cost = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    return (cost.get("flops", 0.0), cost.get("bytes accessed", 0.0),
            coll.get("total", 0), convert_bytes(txt))


def probe_cell(cfg, shape, mesh, impl):
    """XLA cost_analysis counts while-loop bodies ONCE (verified), so the
    scanned production program under-reports flops/bytes/collectives by the
    trip count. Probe: compile the same cell UNROLLED at 1 and 2 periods
    (single microbatch), extrapolate linearly:

        total = fixed + delta * n_periods [ * n_microbatches for train ]

    The optimizer term rides inside delta for train (counted n_mb times,
    < 1% of fwd+bwd flops at seq 4096) — noted in EXPERIMENTS.md.
    """
    from repro.models.transformer import layer_period, n_periods as np_of
    period = layer_period(cfg)
    trips = np_of(cfg)
    import dataclasses
    mb = 1
    shape_p = shape
    if shape.mode == "train":
        from repro.sharding.policy import data_size as ds_of
        mb = max(cfg.num_microbatches, 1)
        b_mb = max(shape.global_batch // mb, 1)
        # per-microbatch probe batch, still sharded over data
        shape_p = dataclasses.replace(shape, global_batch=b_mb)
        mb = shape.global_batch // b_mb

    # the probe must keep the PRODUCTION expert layout: the auto rule keys
    # on total expert bytes, which a 1-2 layer probe would shrink below the
    # grid-EP threshold (discovered in §Perf iteration 3)
    prod_mode = policy_for(cfg, mesh, shape).moe_mode() \
        if cfg.n_experts else "auto"

    def probe_cfg(k):
        kw = dict(n_layers=period * k, scan_layers=False,
                  num_microbatches=1, ep_mode=prod_mode)
        if cfg.family == "encdec":
            # whisper: encoder/decoder have equal depth; scale together
            kw["n_encoder_layers"] = (cfg.n_encoder_layers // trips) * k
        return cfg.replace(**kw)

    f1, b1, c1, v1 = _cost_of(probe_cfg(1), shape_p, mesh, impl)
    f2, b2, c2, v2 = _cost_of(probe_cfg(2), shape_p, mesh, impl)
    df, db, dc, dv = f2 - f1, b2 - b1, c2 - c1, v2 - v1
    fixed = (f1 - df, b1 - db, c1 - dc, v1 - dv)
    total = {
        "flops_total": (fixed[0] + df * trips) * mb,
        "bytes_total": (fixed[1] + db * trips) * mb,
        "collective_bytes_total": (fixed[2] + dc * trips) * mb,
        "convert_bytes_total": (fixed[3] + dv * trips) * mb,
        "probe_trips": trips, "probe_microbatches": mb,
    }
    return total


def _apply_overrides(cfg, overrides):
    if not overrides:
        return cfg
    kw = {}
    for kv in overrides:
        k, v = kv.split("=", 1)
        cur = getattr(cfg, k)
        if isinstance(cur, bool):
            v = v.lower() in ("1", "true")
        elif isinstance(cur, int):
            v = int(v)
        elif isinstance(cur, float):
            v = float(v)
        kw[k] = v
    return cfg.replace(**kw)


def run_cell(arch, shape_name, multi_pod=False, impl=None, verbose=True,
             probe=False, overrides=None, tag_suffix=""):
    cfg = _apply_overrides(get_arch(arch), overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    step, args, pol = build_cell(cfg, shape, mesh, impl=impl)
    donate = getattr(step, "donate_argnums", ())
    lowered = jax.jit(step, donate_argnums=donate).lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    counts = collective_counts(hlo)

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "impl": impl or cfg.attention_impl,
        "n_devices": mesh.devices.size,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops": cost.get("flops", 0.0) if cost else 0.0,
        "bytes_accessed": cost.get("bytes accessed", 0.0) if cost else 0.0,
        "collective_bytes": coll, "collective_counts": counts,
        "memory": {
            k: getattr(mem, k, None) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
        } if mem is not None else {},
        "model_flops_per_token": 6 * cfg.active_param_count(),
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
    }
    if probe:
        t0 = time.time()
        rec.update(probe_cell(cfg.replace(attention_impl=rec["impl"]),
                              shape, mesh, impl))
        rec["probe_s"] = round(time.time() - t0, 2)
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {rec['mesh']} "
              f"impl={rec['impl']}: lower {t_lower:.1f}s compile "
              f"{t_compile:.1f}s")
        print(f"  memory_analysis: {rec['memory']}")
        print(f"  cost_analysis: flops={rec['flops']:.3e} "
              f"bytes={rec['bytes_accessed']:.3e}")
        print(f"  collectives: {coll}")
    os.makedirs(OUT_DIR, exist_ok=True)
    tag = (f"{arch}_{shape_name}_{rec['mesh']}" + (f"_{impl}" if impl else "")
           + tag_suffix)
    with open(os.path.join(OUT_DIR, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def cells():
    for arch in list_archs():
        if arch == "opt13b":
            continue                      # paper model: separate bench
        for shape_name in ("train_4k", "prefill_32k", "decode_32k",
                           "long_500k"):
            yield arch, shape_name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--impl", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--probe", action="store_true",
                    help="also extrapolate true per-step costs (unrolled "
                         "1/2-period probes)")
    ap.add_argument("--continue-on-error", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (perf iterations)")
    ap.add_argument("--tag", default="", help="suffix for the output JSON")
    args = ap.parse_args()

    todo = list(cells()) if args.all else [(args.arch, args.shape)]
    failures = []
    for arch, shape_name in todo:
        try:
            run_cell(arch, shape_name, multi_pod=args.multipod,
                     impl=args.impl, probe=args.probe,
                     overrides=getattr(args, "set"), tag_suffix=args.tag)
        except Exception as e:
            failures.append((arch, shape_name, repr(e)))
            print(f"[dryrun] FAIL {arch} x {shape_name}: {e}",
                  file=sys.stderr)
            traceback.print_exc()
            if not args.continue_on_error:
                raise
    if failures:
        print(f"[dryrun] {len(failures)} failures:")
        for f in failures:
            print("  ", f)
        sys.exit(1)
    print(f"[dryrun] all {len(todo)} cells passed")


if __name__ == "__main__":
    main()
