"""Training launcher: mesh bring-up, sharded state init, checkpoint/resume,
straggler watchdog, elastic restart hooks.

    PYTHONPATH=src python -m repro.launch.train --arch minitron-8b --smoke \
        --steps 20 --batch 8 --seq 64 --ckpt /tmp/run1
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from repro.configs.base import ShapeConfig, get_arch
from repro.models.model_zoo import init_params
from repro.runtime import checkpoint as ckpt_mod
from repro.runtime.data import DataConfig, batch_at, frontend_stub
from repro.runtime.elastic import StepWatchdog, viable_mesh
from repro.runtime.optimizer import OptConfig, default_opt_for
from repro.runtime.train_state import init_train_state, make_train_step
from repro.sharding.params import state_shardings
from repro.sharding.policy import NULL, policy_for


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=args.smoke)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    if args.model_parallel > 1:
        mesh = viable_mesh(jax.devices(), args.model_parallel)
        pol = policy_for(cfg, mesh, shape)
        print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")
    else:
        pol = NULL

    oc = default_opt_for(cfg)
    oc = OptConfig(name=oc.name, lr=1e-3, warmup_steps=5,
                   total_steps=args.steps, moment_dtype=oc.moment_dtype)
    key = jax.random.PRNGKey(0)
    state = init_train_state(cfg, init_params(cfg, key), oc,
                             compress=args.compress_grads)
    start = 0
    if args.ckpt:
        last = ckpt_mod.latest_step(args.ckpt)
        if last is not None:
            shardings = (state_shardings(pol, state)
                         if pol is not NULL else None)
            state = ckpt_mod.restore(args.ckpt, last, state, shardings)
            start = int(state["step"])
            print(f"resumed from step {start}")

    step_fn = jax.jit(make_train_step(cfg, pol, oc,
                                      compress=args.compress_grads))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    global_batch=args.batch)
    wd = StepWatchdog()
    for i in range(start, args.steps):
        batch = batch_at(dc, i)
        if cfg.frontend == "audio":
            batch["frames"] = frontend_stub(dc, cfg, i)
        if cfg.frontend == "vision":
            batch["patches"] = frontend_stub(dc, cfg, i)
        wd.start()
        state, metrics = step_fn(state, batch)
        straggle = wd.stop()
        print(f"step {i}: loss={float(metrics['loss']):.4f} "
              f"gnorm={float(metrics['grad_norm']):.3f}"
              + (" [straggler]" if straggle else ""))
        if args.ckpt and (i + 1) % args.ckpt_every == 0:
            ckpt_mod.save(args.ckpt, int(state["step"]), state, keep=3)
    if args.ckpt:
        ckpt_mod.save(args.ckpt, int(state["step"]), state, keep=3)
    print("done")


if __name__ == "__main__":
    main()
