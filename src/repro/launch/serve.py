"""Serving launcher: offline batched generation with the in-storage
attention engine.

    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke \
        --batch 8 --prompt-len 128 --gen 64 --impl insti_sparf
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import ShapeConfig, get_arch
from repro.models.model_zoo import init_params, make_inputs
from repro.runtime.elastic import viable_mesh
from repro.serving.session import BatchScheduler, Session
from repro.sharding.policy import NULL, policy_for


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--impl", default="insti_sparf",
                    choices=["insti_sparf", "insti_dense", "flexgen_like",
                             "flexgen_sparq"])
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=args.smoke).replace(
        attention_impl=args.impl,
        max_seq=max(512, args.prompt_len + args.gen))
    pol = NULL
    if args.model_parallel > 1:
        mesh = viable_mesh(jax.devices(), args.model_parallel)
        pol = policy_for(cfg, mesh,
                         ShapeConfig("cli", cfg.max_seq, args.batch,
                                     "decode"))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    sess = Session(cfg, params, pol=pol, max_seq=cfg.max_seq)

    sched = BatchScheduler(batch_size=args.batch)
    rng = np.random.default_rng(0)
    for _ in range(args.batch):
        sched.submit(rng.integers(0, cfg.vocab_size,
                                  args.prompt_len).astype(np.int32))
    tokens = sched.next_batch()
    batch = {"tokens": jax.numpy.asarray(tokens)}
    if cfg.frontend != "none":
        batch = make_inputs(cfg, ShapeConfig("p", args.prompt_len,
                                             args.batch, "prefill"), key)

    t0 = time.perf_counter()
    out = sess.generate(batch, args.gen)
    dt = time.perf_counter() - t0
    print(f"impl={args.impl} generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s incl. prefill+compile)")


if __name__ == "__main__":
    main()
