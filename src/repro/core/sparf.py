"""SparF Attention (paper Algorithm 1) — per-worker local math.

Every function here operates on ONE worker's shard of the paged KV store
(`core.paged_kv`), i.e. inside the shard_map that models the CSD array
(`core.offload`). Workers return flash-style partial statistics
(m = running max, l = denominator, acc = weighted value sum) so the caller
can combine across sequence stripes of the same head with a pmax+psum —
only attention outputs ever cross the interconnect.

Step numbering follows Algorithm 1:
  1   top-r channels of |q|
  2-3 page-granular channel load + filter (embedding-indexed K copy)
  4   approximate scores ŝ with the ||q_r||1/||q||1 temperature correction
  5-6 top-k token selection (per-shard budget k_loc = k / seq_shards)
  7   α = selected probability mass (combined globally by the caller)
  8-9 page-granular token load + filter (token-indexed K,V)
  10  exact softmax over the selected tokens
  11  out = α·Attn_sel + (1-α)·v̄   (applied by the caller after combine)

The jnp reference implements the *math*; the page-granular *access pattern*
(whole-page DMA + in-VMEM filter) is what kernels/sparf_decode.py realizes.
The math is identical by construction: steps 3/9 discard exactly the bytes
page-granularity over-fetched.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.paged_kv import KVLayout, gather_pages, local_positions

NEG_INF = -1e30


class Partial(NamedTuple):
    """Flash-combine partial statistics for a set of scored tokens."""
    m: jax.Array      # [B, kv_loc, G]         running max of logits
    l: jax.Array      # [B, kv_loc, G]         sum exp(logit - m)
    acc: jax.Array    # [B, kv_loc, G, hd]     sum exp(logit - m) * v


class SparFPartial(NamedTuple):
    exact: Partial            # stats over the selected tokens (steps 8-10)
    m_hat: jax.Array          # [B, kv_loc, G] max of approximate logits
    l_hat_all: jax.Array      # [B, kv_loc, G] Σ exp over ALL local tokens
    l_hat_sel: jax.Array      # [B, kv_loc, G] Σ exp over selected tokens


def _valid_mask(layout: KVLayout, stripe, length):
    """[S_loc] bool: which local slots hold live tokens (< length)."""
    pos = local_positions(layout, stripe)
    return pos < length, pos


def _token_valid(layout, stripe, length, page_valid, b, kv):
    """[B, kv_loc, S_loc] bool: live (< length) AND page not retired."""
    valid, _ = _valid_mask(layout, stripe, length)
    tok = jnp.broadcast_to(valid[None, None, :], (b, kv, valid.shape[0]))
    if page_valid is not None:
        pv = jnp.repeat(page_valid, layout.page, axis=-1)
        tok = tok & pv
    return tok


def dense_worker(layout: KVLayout, q, k_pages, v_pages, stripe, length,
                 page_valid=None) -> Partial:
    """Dense decode attention over one worker's pages (InstI-Dense).

    q: [B, kv_loc, G, hd]; k_pages/v_pages: [B, kv_loc, P_loc, page, hd];
    page_valid: [B, kv_loc, P_loc] bool or None (FTL retirement mask).
    """
    b, kv, g, hd = q.shape
    k = k_pages.reshape(b, kv, -1, hd)          # [B, kv, S_loc, hd]
    v = v_pages.reshape(b, kv, -1, hd)
    valid = _token_valid(layout, stripe, length, page_valid, b, kv)
    # compute in storage dtype with f32 accumulation: avoids materializing
    # an f32 copy of the whole KV shard (§Perf iteration 1)
    logits = jnp.einsum("bkgh,bksh->bkgs", q.astype(k.dtype), k,
                        preferred_element_type=jnp.float32) / np.sqrt(hd)
    logits = jnp.where(valid[:, :, None, :], logits, NEG_INF)
    m = jnp.max(logits, axis=-1)
    p = jnp.exp(logits - m[..., None])
    p = jnp.where(valid[:, :, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bkgs,bksh->bkgh", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return Partial(m, l, acc)


def combine_partials(part: Partial, axis_name=None,
                     wire_dtype=None) -> jax.Array:
    """Combine flash partials across the model axis (or locally if None).
    Returns [B, kv_loc, G, hd] float32 attention output.

    wire_dtype (e.g. bf16) compresses the psum'd tensors — halves the
    decode collective term; the max-normalized exponentials are in [0, 1]
    so bf16 relative error is benign (§Perf iteration)."""
    if axis_name is None:
        return part.acc / jnp.maximum(part.l, 1e-20)[..., None]
    m_glob = jax.lax.pmax(part.m, axis_name)
    corr = jnp.exp(part.m - m_glob)
    l = part.l * corr
    acc = part.acc * corr[..., None]
    if wire_dtype is not None:
        l, acc = l.astype(wire_dtype), acc.astype(wire_dtype)
    l = jax.lax.psum(l, axis_name).astype(jnp.float32)
    acc = jax.lax.psum(acc, axis_name).astype(jnp.float32)
    return acc / jnp.maximum(l, 1e-20)[..., None]


def sparf_worker(layout: KVLayout, scfg, q, k_pages, v_pages, k_embed,
                 block_table, stripe, length,
                 page_valid=None) -> SparFPartial:
    """SparF Algorithm 1 on one worker's shard.

    q: [B, kv_loc, G, hd]
    k_pages/v_pages: [B, kv_loc, P_loc, page, hd]
    k_embed: [B, kv_loc, hd, S_loc]
    page_valid: [B, kv_loc, P_loc] bool or None (FTL retirement mask)
    """
    b, kv, g, hd = q.shape
    r = min(scfg.rank_r, hd)
    k_budget = max(1, scfg.top_k // max(layout.seq_shards, 1))
    s_loc = layout.seq_loc
    k_budget = min(k_budget, s_loc)
    valid = _token_valid(layout, stripe, length, page_valid, b, kv)
    qf = q.astype(jnp.float32)

    # ---- step 1: top-r channels of |q| ----
    _, chan_idx = jax.lax.top_k(jnp.abs(qf), r)               # [B,kv,G,r]
    q_r = jnp.take_along_axis(qf, chan_idx, axis=-1)          # [B,kv,G,r]

    # ---- steps 2-3: channel-gather from the embedding-indexed copy ----
    # (kernel fetches channel *groups* of size n and filters; math identical)
    # gather in storage dtype with FLATTENED (G*r) indices on the
    # un-broadcast store: a [B,kv,G,hd,S] broadcast of the whole copy would
    # otherwise materialize G x the KV bytes (§Perf iterations 1+4)
    k_r = jnp.take_along_axis(
        k_embed, chan_idx.reshape(b, kv, g * r)[..., None], axis=2
    ).reshape(b, kv, g, r, s_loc)                             # [B,kv,G,r,S]

    # ---- step 4: approximate scores with L1 temperature correction ----
    l1_frac = (jnp.sum(jnp.abs(q_r), -1)
               / jnp.maximum(jnp.sum(jnp.abs(qf), -1), 1e-20))  # [B,kv,G]
    temp = jnp.sqrt(hd * jnp.maximum(l1_frac, 1e-20))
    s_hat = jnp.einsum("bkgr,bkgrs->bkgs", q_r.astype(k_r.dtype), k_r,
                       preferred_element_type=jnp.float32) / temp[..., None]
    s_hat = jnp.where(valid[:, :, None, :], s_hat, NEG_INF)

    # ---- steps 5-6: top-k token selection (per-stripe budget) ----
    top_vals, tok_idx = jax.lax.top_k(s_hat, k_budget)        # [B,kv,G,k]

    # ---- step 7 (local part): selected / total approximate mass ----
    m_hat = jnp.max(s_hat, axis=-1)
    e_all = jnp.where(valid[:, :, None, :],
                      jnp.exp(s_hat - m_hat[..., None]), 0.0)
    l_hat_all = jnp.sum(e_all, axis=-1)
    sel_valid = top_vals > NEG_INF / 2
    l_hat_sel = jnp.sum(jnp.where(sel_valid,
                                  jnp.exp(top_vals - m_hat[..., None]), 0.0),
                        axis=-1)

    # ---- steps 8-9: page-granular token fetch + in-buffer filter ----
    page_idx = tok_idx // layout.page                          # [B,kv,G,k]
    slot_idx = tok_idx % layout.page
    # fetch whole pages (the flash access; block_table = FTL translation),
    # flattened (G*k) indices against the un-broadcast store (§Perf it. 4)
    flat_pages = jnp.take_along_axis(
        block_table, page_idx.reshape(b, kv, g * k_budget), axis=-1)
    k_sel_pages = jnp.take_along_axis(
        k_pages, flat_pages[..., None, None], axis=2)
    v_sel_pages = jnp.take_along_axis(
        v_pages, flat_pages[..., None, None], axis=2)
    # NFC filter: keep only the selected slot of each fetched page
    flat_slots = slot_idx.reshape(b, kv, g * k_budget)
    k_sel = jnp.take_along_axis(
        k_sel_pages, flat_slots[..., None, None], axis=-2
    )[..., 0, :].reshape(b, kv, g, k_budget, hd)
    v_sel = jnp.take_along_axis(
        v_sel_pages, flat_slots[..., None, None], axis=-2
    )[..., 0, :].reshape(b, kv, g, k_budget, hd)

    # ---- step 10: exact softmax over selected tokens ----
    logits = jnp.einsum("bkgh,bkgsh->bkgs", qf.astype(k_sel.dtype), k_sel,
                        preferred_element_type=jnp.float32) / np.sqrt(hd)
    logits = jnp.where(sel_valid, logits, NEG_INF)
    m2 = jnp.max(logits, axis=-1)
    p = jnp.where(sel_valid, jnp.exp(logits - m2[..., None]), 0.0)
    l2 = jnp.sum(p, axis=-1)
    acc2 = jnp.einsum("bkgs,bkgsh->bkgh", p.astype(v_sel.dtype), v_sel,
                      preferred_element_type=jnp.float32)
    return SparFPartial(Partial(m2, l2, acc2), m_hat, l_hat_all, l_hat_sel)


def combine_sparf(part: SparFPartial, v_mean, axis_name=None,
                  wire_dtype=None) -> jax.Array:
    """Global combine of SparF partials + step 11 mean-V compensation.

    v_mean: [B, kv_loc, hd] f32 — running mean of ALL V vectors (v̄).
    Returns [B, kv_loc, G, hd] f32.
    """
    out_exact = combine_partials(part.exact, axis_name, wire_dtype)
    if axis_name is None:
        alpha = part.l_hat_sel / jnp.maximum(part.l_hat_all, 1e-20)
    else:
        m_glob = jax.lax.pmax(part.m_hat, axis_name)
        corr = jnp.exp(part.m_hat - m_glob)
        sel = part.l_hat_sel * corr
        tot = part.l_hat_all * corr
        if wire_dtype is not None:
            sel, tot = sel.astype(wire_dtype), tot.astype(wire_dtype)
        sel = jax.lax.psum(sel, axis_name).astype(jnp.float32)
        tot = jax.lax.psum(tot, axis_name).astype(jnp.float32)
        alpha = sel / jnp.maximum(tot, 1e-20)
    alpha = jnp.clip(alpha, 0.0, 1.0)[..., None]               # [B,kv,G,1]
    return alpha * out_exact + (1.0 - alpha) * v_mean[:, :, None, :]
