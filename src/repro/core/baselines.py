"""Sparse-attention accuracy baselines (paper Fig. 11): H2O, local window,
and plain SparQ — all on flat [B, S, KV, hd] K/V, used by the accuracy
benchmark at small scale. SparF's production path lives in core/sparf.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def _gqa(q, n_kv):
    b, h, hd = q.shape
    return q.reshape(b, n_kv, h // n_kv, hd)


def dense_decode(q, k, v, length):
    """Oracle: full attention over live tokens. q:[B,H,hd], k/v:[B,S,KV,hd]."""
    b, s, kv, hd = k.shape
    qg = _gqa(q, kv)
    logits = jnp.einsum("bkgh,bskh->bkgs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(hd)
    mask = (jnp.arange(s) < length)[None, None, None, :]
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, -1)
    out = jnp.einsum("bkgs,bskh->bkgh", p, v.astype(jnp.float32))
    return out.reshape(q.shape).astype(q.dtype)


def topk_mask_decode(q, k, v, length, keep, scores):
    """Attend only to the top-`keep` tokens per head ranked by `scores`
    [B,KV,G,S] (higher = keep)."""
    b, s, kv, hd = k.shape
    qg = _gqa(q, kv)
    mask_live = (jnp.arange(s) < length)[None, None, None, :]
    scores = jnp.where(mask_live, scores, NEG_INF)
    _, idx = jax.lax.top_k(scores, min(keep, s))
    sel = jnp.zeros(scores.shape, bool).at[
        jnp.arange(b)[:, None, None, None],
        jnp.arange(kv)[None, :, None, None],
        jnp.arange(scores.shape[2])[None, None, :, None], idx].set(True)
    logits = jnp.einsum("bkgh,bskh->bkgs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(hd)
    logits = jnp.where(sel & mask_live, logits, NEG_INF)
    p = jax.nn.softmax(logits, -1)
    out = jnp.einsum("bkgs,bskh->bkgh", p, v.astype(jnp.float32))
    return out.reshape(q.shape).astype(q.dtype)


def h2o_decode(q, k, v, length, keep, acc_scores, recent=None):
    """H2O heavy-hitter: keep tokens with the largest *accumulated* attention
    mass (acc_scores [B,KV,S], maintained by the caller across steps) plus a
    recent window."""
    b, s, kv, hd = k.shape
    g = q.shape[1] // kv
    recent = recent if recent is not None else max(1, keep // 4)
    pos = jnp.arange(s)
    recency_bonus = jnp.where(pos >= length - recent, 1e9, 0.0)
    sc = acc_scores[:, :, None, :] + recency_bonus[None, None, None, :]
    sc = jnp.broadcast_to(sc, (b, kv, g, s))
    return topk_mask_decode(q, k, v, length, keep, sc)


def local_decode(q, k, v, length, keep):
    """Sliding-window attention: the most recent `keep` tokens."""
    b, s, kv, hd = k.shape
    g = q.shape[1] // kv
    pos = jnp.arange(s).astype(jnp.float32)
    sc = jnp.broadcast_to(pos[None, None, None, :], (b, kv, g, s))
    return topk_mask_decode(q, k, v, length, keep, sc)


def sparq_decode(q, k, v, length, r, keep, v_mean=None):
    """Vanilla SparQ (Ribar et al.) on flat K/V: top-r channel approximate
    scores -> top-k tokens -> exact attention + mean-V compensation."""
    b, s, kv, hd = k.shape
    qg = _gqa(q, kv).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    mask_live = (jnp.arange(s) < length)[None, None, None, :]
    _, chan = jax.lax.top_k(jnp.abs(qg), min(r, hd))          # [B,KV,G,r]
    q_r = jnp.take_along_axis(qg, chan, -1)
    k_r = jnp.take_along_axis(
        kf.transpose(0, 2, 3, 1)[:, :, None],                  # [B,KV,1,hd,S]
        chan[..., None], axis=3)                               # [B,KV,G,r,S]
    l1 = (jnp.sum(jnp.abs(q_r), -1)
          / jnp.maximum(jnp.sum(jnp.abs(qg), -1), 1e-20))
    temp = jnp.sqrt(hd * jnp.maximum(l1, 1e-20))
    s_hat = jnp.einsum("bkgr,bkgrs->bkgs", q_r, k_r) / temp[..., None]
    s_hat = jnp.where(mask_live, s_hat, NEG_INF)
    p_hat = jax.nn.softmax(s_hat, -1)
    top_p, idx = jax.lax.top_k(s_hat, min(keep, s))
    alpha = jnp.sum(jnp.take_along_axis(p_hat, idx, -1), -1)   # [B,KV,G]
    out_sel = topk_mask_decode(q, k, v, length, keep, s_hat)
    out_sel = _gqa(out_sel, kv).astype(jnp.float32)
    if v_mean is None:
        live = mask_live[..., None]
        v_mean = (jnp.sum(jnp.where(live[:, 0, 0], k[..., :0], 0), axis=1))
        v_mean = jnp.sum(
            jnp.where((jnp.arange(s) < length)[None, :, None, None],
                      v.astype(jnp.float32), 0.0), axis=1) / jnp.maximum(
                          length, 1)
    out = (alpha[..., None] * out_sel
           + (1 - alpha[..., None]) * v_mean[:, :, None, :])
    return out.reshape(q.shape).astype(q.dtype)
