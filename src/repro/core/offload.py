"""In-storage attention offloading — the CSD-array execution model.

The `model` mesh axis is the CSD array: W workers, each owning a
(kv-head shard × sequence stripe) of the paged KV store. Decode attention
executes INSIDE a shard_map over that axis, where each worker's KV bytes
are local HBM reads; what crosses the interconnect is exactly

    in : q        [B, H, hd]      (replicated broadcast, ~KB)
    out: pmax/psum of flash partials  [B, H, hd + 2]   (~KB)

— the paper's "only q,k,v vectors and attention outputs are transmitted",
with the same s/2-style traffic reduction measurable in the lowered HLO.

The FlexGen-like baseline is also provided: it all-gathers the KV pages to
every worker each step (KV travels the narrow link), reproducing the
PCIe-bound access pattern the paper measures against.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import sparf as sparf_mod
from repro.core.paged_kv import KVLayout, cache_specs
from repro.core.sparf import (Partial, SparFPartial, combine_partials,
                              combine_sparf, dense_worker, sparf_worker)
from repro.sharding.policy import NullPolicy

AXIS = "model"


def _scatter_full(x_loc, kv_shard, kv_loc, n_kv, fill):
    """Place a worker's [B, kv_loc, ...] stats into the full [B, KV, ...]
    tensor at its head offset (others = `fill`) so a single psum over the
    model axis both combines stripes and assembles heads."""
    full_shape = (x_loc.shape[0], n_kv) + x_loc.shape[2:]
    full = jnp.full(full_shape, fill, x_loc.dtype)
    return jax.lax.dynamic_update_slice_in_dim(
        full, x_loc, kv_shard * kv_loc, axis=1)


def _worker_ids(layout: KVLayout):
    w = jax.lax.axis_index(AXIS)
    kv_shard = w // layout.seq_shards
    stripe = w % layout.seq_shards
    return kv_shard, stripe


def _reshape_q(q, n_kv):
    """[B, H, hd] -> [B, KV, G, hd] (GQA grouping)."""
    b, h, hd = q.shape
    return q.reshape(b, n_kv, h // n_kv, hd)


def _flatten_out(out):
    """[B, KV, G, hd] -> [B, H, hd]."""
    b, kv, g, hd = out.shape
    return out.reshape(b, kv * g, hd)


# ----------------------------------------------------------------------------
# single-worker (off-mesh) paths
# ----------------------------------------------------------------------------

def _local_dense(layout, q, cache, length):
    part = dense_worker(layout, _reshape_q(q, layout.n_kv_heads),
                        cache["k_pages"][:, 0], cache["v_pages"][:, 0],
                        0, length,
                        page_valid=cache.get("page_valid",
                                             [None])[:, 0]
                        if "page_valid" in cache else None)
    return _flatten_out(combine_partials(part))


def _local_sparf(layout, scfg, q, cache, length):
    part = sparf_worker(layout, scfg, _reshape_q(q, layout.n_kv_heads),
                        cache["k_pages"][:, 0], cache["v_pages"][:, 0],
                        cache["k_embed"][:, 0], cache["block_table"][:, 0],
                        0, length,
                        page_valid=cache.get("page_valid",
                                             [None])[:, 0]
                        if "page_valid" in cache else None)
    v_mean = cache["v_sum"] / jnp.maximum(length, 1).astype(jnp.float32)
    return _flatten_out(combine_sparf(part, v_mean))


# ----------------------------------------------------------------------------
# offloaded (CSD-array) paths
# ----------------------------------------------------------------------------

def _offloaded(cfg, pol, layout: KVLayout, q, cache, length, impl):
    mesh = pol.mesh
    specs = cache_specs(layout, pol)
    b = pol.batch_spec
    scfg = cfg.sparf

    wire = (None if cfg.combine_dtype in ("float32", "")
            else jnp.dtype(cfg.combine_dtype))

    def body(q, k_pages, v_pages, k_embed, block_table, v_sum, page_valid):
        kv_shard, stripe = _worker_ids(layout)
        qg = _reshape_q(q, layout.n_kv_heads)
        # slice this worker's q heads
        q_loc = jax.lax.dynamic_slice_in_dim(qg, kv_shard * layout.kv_loc,
                                             layout.kv_loc, axis=1)
        kp, vp = k_pages[:, 0], v_pages[:, 0]
        pv = page_valid[:, 0]
        if impl == "insti_sparf":
            part = sparf_worker(layout, scfg, q_loc, kp, vp,
                                k_embed[:, 0], block_table[:, 0],
                                stripe, length, page_valid=pv)
            exact = Partial(
                _scatter_full(part.exact.m, kv_shard, layout.kv_loc,
                              layout.n_kv_heads, sparf_mod.NEG_INF),
                _scatter_full(part.exact.l, kv_shard, layout.kv_loc,
                              layout.n_kv_heads, 0.0),
                _scatter_full(part.exact.acc, kv_shard, layout.kv_loc,
                              layout.n_kv_heads, 0.0))
            full = SparFPartial(
                exact,
                _scatter_full(part.m_hat, kv_shard, layout.kv_loc,
                              layout.n_kv_heads, sparf_mod.NEG_INF),
                _scatter_full(part.l_hat_all, kv_shard, layout.kv_loc,
                              layout.n_kv_heads, 0.0),
                _scatter_full(part.l_hat_sel, kv_shard, layout.kv_loc,
                              layout.n_kv_heads, 0.0))
            v_mean = v_sum / jnp.maximum(length, 1).astype(jnp.float32)
            out = combine_sparf(full, v_mean, AXIS, wire_dtype=wire)
        elif impl == "insti_dense":
            part = dense_worker(layout, q_loc, kp, vp, stripe, length,
                                page_valid=pv)
            full = Partial(
                _scatter_full(part.m, kv_shard, layout.kv_loc,
                              layout.n_kv_heads, sparf_mod.NEG_INF),
                _scatter_full(part.l, kv_shard, layout.kv_loc,
                              layout.n_kv_heads, 0.0),
                _scatter_full(part.acc, kv_shard, layout.kv_loc,
                              layout.n_kv_heads, 0.0))
            out = combine_partials(full, AXIS, wire_dtype=wire)
        else:  # flexgen_like / flexgen_sparq: KV travels the link each step
            k_all = jax.lax.all_gather(kp, AXIS)     # [W, B, kv_loc, P, pg, hd]
            v_all = jax.lax.all_gather(vp, AXIS)
            out = _gathered_attention(cfg, layout, qg, k_all, v_all,
                                      length, impl,
                                      jax.lax.all_gather(k_embed[:, 0], AXIS),
                                      jax.lax.all_gather(block_table[:, 0],
                                                         AXIS),
                                      v_sum)
        return _flatten_out(out).astype(q.dtype)

    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(b, None, None), specs["k_pages"], specs["v_pages"],
                  specs["k_embed"], specs["block_table"], P(b, None, None),
                  specs["page_valid"]),
        out_specs=P(b, None, None), check_vma=False,
    )(q, cache["k_pages"], cache["v_pages"], cache["k_embed"],
      cache["block_table"], cache["v_sum"], cache["page_valid"])


def _gathered_attention(cfg, layout, qg, k_all, v_all, length, impl,
                        ke_all, bt_all, v_sum):
    """FlexGen-like: full KV gathered to every worker (the PCIe pattern),
    then attention computed locally on the reassembled cache."""
    w, b = k_all.shape[0], k_all.shape[1]
    # reassemble [W, B, kv_loc, ...] -> single-worker layout with all heads
    kv, hd = layout.n_kv_heads, layout.head_dim

    def reassemble(pages):
        # [W, B, kv_loc, P_loc, page, hd] -> [B, KV, P_loc*seq, page, hd]
        x = pages.reshape(layout.kv_shards, layout.seq_shards, b,
                          layout.kv_loc, layout.pages_loc, layout.page, hd)
        x = x.transpose(2, 0, 3, 4, 1, 5, 6)    # B,kvs,kvloc,Ploc,seqs,pg,hd
        return x.reshape(b, kv, layout.n_pages, layout.page, hd)

    k_pages = reassemble(k_all)
    v_pages = reassemble(v_all)
    flat_layout = KVLayout(
        n_kv_heads=kv, head_dim=hd, page=layout.page,
        n_pages=layout.n_pages, n_workers=1, kv_shards=1, seq_shards=1)
    if impl == "flexgen_sparq":
        # embedding-indexed copy also crosses the link
        ke = ke_all.reshape(layout.kv_shards, layout.seq_shards, b,
                            layout.kv_loc, hd, layout.seq_loc)
        ke = ke.transpose(2, 0, 3, 4, 1, 5).reshape(b, kv, hd, -1)
        # NOTE: flat view interleaves stripes; rebuild token order
        ke = _destride_embed(layout, ke)
        bt = jnp.broadcast_to(
            jnp.arange(layout.n_pages, dtype=jnp.int32),
            (b, kv, layout.n_pages))
        part = sparf_worker(flat_layout, cfg.sparf, qg, k_pages, v_pages,
                            ke, bt, 0, length)
        v_mean = v_sum / jnp.maximum(length, 1).astype(jnp.float32)
        return combine_sparf(part, v_mean)
    part = dense_worker(flat_layout, qg, k_pages, v_pages, 0, length)
    return combine_partials(part)


def _destride_embed(layout, ke):
    """Reorder an embedding-indexed copy gathered from strided stripes into
    contiguous token order: [B, KV, hd, seqs*S_loc] stripe-major ->
    token-major."""
    b, kv, hd = ke.shape[:3]
    x = ke.reshape(b, kv, hd, layout.seq_shards, layout.pages_loc, layout.page)
    x = x.transpose(0, 1, 2, 4, 3, 5)   # pages-major, stripe, slot
    return x.reshape(b, kv, hd, layout.n_pages * layout.page)


# ----------------------------------------------------------------------------
# public entry
# ----------------------------------------------------------------------------

def decode_attention(cfg, pol, layout: KVLayout, q, cache, length,
                     impl: Optional[str] = None):
    """One decode step of attention against the paged KV store.

    q: [B, H, hd] (current token); returns [B, H, hd].
    """
    impl = impl or cfg.attention_impl
    if isinstance(pol, NullPolicy) or layout.n_workers == 1:
        if impl in ("insti_sparf", "flexgen_sparq"):
            return _local_sparf(layout, cfg.sparf, q, cache, length
                                ).astype(q.dtype)
        return _local_dense(layout, q, cache, length).astype(q.dtype)
    return _offloaded(cfg, pol, layout, q, cache, length, impl)
