"""Operator-placement planner — the paper's §III-B analysis as code.

InstInfer's task split is NOT phase-level (prefill vs decode) but
operator-level, decided by each operator's arithmetic intensity against
the roofline of each engine (paper Fig. 6): an operator belongs on the
storage side iff it is memory-bound there AND its operand bytes live in
storage (so moving the operator is cheaper than moving the bytes).

This module reproduces that decision procedure for (a) the paper's
A6000 + Zynq7045-CSD testbed — recovering exactly the paper's split —
and (b) the TPU transplant (MXU compute side vs KV-shard storage side),
which is what core/offload.py implements. `benchmarks/placement.py`
prints the full table (the Fig. 6 reproduction).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List


@dataclass(frozen=True)
class Engine:
    name: str
    flops: float              # peak FLOP/s
    mem_bw: float             # bytes/s to its local operand store
    link_bw: float            # bytes/s for small control/result transfers
    bulk_bw: float = 0.0      # bytes/s for bulk operand egress (an SSD's
                              # external FS path << its internal channels;
                              # equal to link_bw on TPU). 0 -> link_bw.

    @property
    def egress(self) -> float:
        return self.bulk_bw or self.link_bw


# the paper's testbed (Fig. 6) and the TPU transplant. The CSD's bulk
# egress is the SSD-over-filesystem path (5.5 GB/s x 0.30 efficiency) —
# the whole reason KV must not travel (paper §III-A).
GPU_A6000 = Engine("A6000", 38.7e12, 768e9, 12e9)
CSD_ZYNQ = Engine("InstCSD", 0.44e12, 11.2e9, 12e9, bulk_bw=1.65e9)
TPU_MXU = Engine("v5e-MXU", 197e12, 819e9, 50e9)
TPU_KVSHARD = Engine("v5e-KV-shard", 197e12, 819e9, 50e9)


@dataclass(frozen=True)
class Operator:
    name: str
    phase: str                # prefill | decode
    flops: float              # per step
    bytes_weights: float      # operand bytes resident on the compute side
    bytes_kv: float           # operand bytes resident on the storage side
    out_bytes: float          # result bytes that must reach the compute side

    @property
    def intensity(self) -> float:
        return self.flops / max(self.bytes_weights + self.bytes_kv, 1.0)


def opt13b_operators(batch: int = 64, seq: int = 1024,
                     d: int = 5120, n_layers: int = 40) -> List[Operator]:
    """The paper's OPT-13B operator set, per decode/prefill step."""
    p_lin = 12 * d * d * n_layers          # qkv/o/ffn weights (~params)
    kv = 2 * 2 * batch * seq * d * n_layers
    ops = []
    # prefill (per full sequence)
    t = batch * seq
    ops.append(Operator("QKV/O-Proj+FFN", "prefill", 2 * p_lin * t,
                        2 * p_lin, 0, 2 * t * d))
    ops.append(Operator("Attention", "prefill",
                        4 * batch * seq * seq * d * n_layers, 0,
                        kv, 2 * t * d))
    # decode (per token step)
    ops.append(Operator("QKV/O-Proj+FFN", "decode", 2 * p_lin * batch,
                        2 * p_lin, 0, 2 * batch * d))
    ops.append(Operator("Logit+Attend", "decode",
                        4 * batch * seq * d * n_layers, 0, kv,
                        2 * batch * d * n_layers))
    return ops


def time_on(op: Operator, eng: Engine, other: Engine, *,
            storage_side: bool) -> float:
    """Execution time of `op` on `eng`. Operand bytes living on the OTHER
    engine cross at that engine's bulk-egress bandwidth; small results
    cross at link bandwidth."""
    local = op.bytes_kv if storage_side else op.bytes_weights
    remote = op.bytes_weights if storage_side else op.bytes_kv
    t_compute = op.flops / eng.flops
    t_local = local / eng.mem_bw
    t_remote = remote / other.egress + op.out_bytes / eng.link_bw
    return max(t_compute, t_local) + t_remote


def place(op: Operator, compute: Engine, storage: Engine) -> dict:
    t_c = time_on(op, compute, storage, storage_side=False)
    t_s = time_on(op, storage, compute, storage_side=True)
    return {"op": op.name, "phase": op.phase,
            "intensity": op.intensity,
            "t_compute_side_s": t_c, "t_storage_side_s": t_s,
            "placement": "storage" if t_s < t_c else "compute"}


def plan(operators: List[Operator], compute: Engine,
         storage: Engine) -> List[dict]:
    return [place(op, compute, storage) for op in operators]


def paper_plan(batch: int = 64) -> List[dict]:
    """Reproduces the paper's split: everything on the GPU except
    decode-phase Logit+Attend, which goes to the CSD."""
    return plan(opt13b_operators(batch), GPU_A6000, CSD_ZYNQ)
