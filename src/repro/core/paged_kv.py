"""Paged, dual-indexed KV storage — the TPU analogue of InstInfer's
KV-cache-oriented FTL (paper §IV-C).

Layout (per attention layer; stacked over layers at the top level):

  k_pages : [B, W, kv_loc, P_loc, page, hd]   token-indexed K
  v_pages : [B, W, kv_loc, P_loc, page, hd]   token-indexed V
  k_embed : [B, W, kv_loc, hd, S_loc]         embedding-indexed K (dual copy)
  v_sum   : [B, KV, hd] f32                   running ΣV for mean-V (Alg.1 v̄)
  block_table : [B, W, kv_loc, P_loc] i32     logical->physical page map (FTL)

W = size of the `model` mesh axis = the "CSD array". Each worker w owns
kv-head shard w // seq_shards and the page stripe w % seq_shards — the
paper's head-major, channel-strided placement: heads across CSDs, pages of
one head strided across "flash channels" (here: sequence shards) so every
head can use full aggregate bandwidth.

page = 16 tokens (paper: 16 tokens x 128 fp16 = one 4KB flash page). All
reads/writes are page-granular; the dual-step load fetches whole pages and
filters weak tokens afterwards (NFC filter), which on TPU keeps every
HBM->VMEM DMA tile-aligned.

The K matrix is stored TWICE (token-indexed + embedding-indexed) — the
paper's capacity-for-bandwidth trade; the transposed copy makes the top-r
channel gather a contiguous-lane read.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class KVLayout:
    """Static layout descriptor (not traced)."""
    n_kv_heads: int
    head_dim: int
    page: int            # tokens per page (paper's group size m)
    n_pages: int         # total logical pages (max_seq / page)
    n_workers: int       # W = model-axis size (the CSD array)
    kv_shards: int       # heads split
    seq_shards: int      # page stripes per head

    @property
    def kv_loc(self) -> int:
        return self.n_kv_heads // self.kv_shards

    @property
    def pages_loc(self) -> int:
        return self.n_pages // self.seq_shards

    @property
    def seq_loc(self) -> int:
        return self.pages_loc * self.page

    @property
    def max_seq(self) -> int:
        return self.n_pages * self.page

    # ---- address translation (the FTL) ----
    def page_of(self, pos):
        return pos // self.page

    def slot_of(self, pos):
        return pos % self.page

    def stripe_of(self, page):
        """Which sequence-shard owns a global page (strided placement)."""
        return page % self.seq_shards

    def local_page(self, page):
        return page // self.seq_shards

    def global_page(self, stripe, local_page):
        return local_page * self.seq_shards + stripe

    def worker_of(self, kv_shard, stripe):
        return kv_shard * self.seq_shards + stripe


def make_layout(cfg, max_seq: int, n_workers: int) -> KVLayout:
    page = cfg.sparf.page_tokens
    n_pages = -(-max_seq // page)
    kv = max(cfg.n_kv_heads, 1)
    kv_shards = math.gcd(kv, n_workers)
    seq_shards = n_workers // kv_shards
    # pages must stripe evenly
    n_pages = -(-n_pages // seq_shards) * seq_shards
    return KVLayout(n_kv_heads=kv, head_dim=cfg.head_dim, page=page,
                    n_pages=n_pages, n_workers=n_workers,
                    kv_shards=kv_shards, seq_shards=seq_shards)


def init_layer_cache(layout: KVLayout, batch: int, dtype) -> dict:
    L = layout
    shape_pages = (batch, L.n_workers, L.kv_loc, L.pages_loc, L.page, L.head_dim)
    return {
        "k_pages": jnp.zeros(shape_pages, dtype),
        "v_pages": jnp.zeros(shape_pages, dtype),
        "k_embed": jnp.zeros((batch, L.n_workers, L.kv_loc, L.head_dim,
                              L.seq_loc), dtype),
        "v_sum": jnp.zeros((batch, L.n_kv_heads, L.head_dim), jnp.float32),
        "block_table": jnp.broadcast_to(
            jnp.arange(L.pages_loc, dtype=jnp.int32),
            (batch, L.n_workers, L.kv_loc, L.pages_loc)),
        "page_valid": jnp.ones((batch, L.n_workers, L.kv_loc, L.pages_loc),
                               bool),
    }


def cache_specs(layout: KVLayout, pol) -> dict:
    """PartitionSpecs for one layer's cache under the given policy."""
    from jax.sharding import PartitionSpec as P
    b = getattr(pol, "batch_spec", None)
    w = "model" if layout.n_workers > 1 else None
    return {
        "k_pages": P(b, w, None, None, None, None),
        "v_pages": P(b, w, None, None, None, None),
        "k_embed": P(b, w, None, None, None),
        "v_sum": P(b, None, None),
        "block_table": P(b, w, None, None),
        "page_valid": P(b, w, None, None),
    }


def append_token(layout: KVLayout, cache: dict, k_new, v_new, pos) -> dict:
    """Append one token's K/V (decode step). k_new, v_new: [B, KV, hd].

    Page-granular write: the token lands in its page slot; the
    embedding-indexed copy gets the matching column. pos: traced scalar.
    """
    L = layout
    b = k_new.shape[0]
    page = L.page_of(pos)
    slot = L.slot_of(pos)
    stripe = L.stripe_of(page)
    lp = L.local_page(page)
    # workers that receive this token: one per kv shard
    ws = jnp.arange(L.kv_shards, dtype=jnp.int32) * L.seq_shards + stripe
    # advanced indexing puts the ws dim first: values must be [kvs, B, kv_loc, hd]
    k_r = k_new.reshape(b, L.kv_shards, L.kv_loc, L.head_dim).swapaxes(0, 1)
    v_r = v_new.reshape(b, L.kv_shards, L.kv_loc, L.head_dim).swapaxes(0, 1)
    cache = dict(cache)
    cache["k_pages"] = cache["k_pages"].at[:, ws, :, lp, slot, :].set(
        k_r.astype(cache["k_pages"].dtype))
    cache["v_pages"] = cache["v_pages"].at[:, ws, :, lp, slot, :].set(
        v_r.astype(cache["v_pages"].dtype))
    t_loc = lp * L.page + slot
    cache["k_embed"] = cache["k_embed"].at[:, ws, :, :, t_loc].set(
        k_r.astype(cache["k_embed"].dtype))
    cache["v_sum"] = cache["v_sum"] + v_new.astype(jnp.float32)
    return cache


def write_prefill(layout: KVLayout, cache: dict, k, v, lengths=None) -> dict:
    """Bulk write after prefill. k, v: [B, S, KV, hd] (S <= max_seq).

    This is the layer-wise KV "transmission" from compute to storage layout:
    a reshape/transpose into the strided page placement. Under pjit the
    reshard overlaps the next layer's compute (paper's layer-wise pipeline).
    """
    L = layout
    bsz, s, kv, hd = k.shape
    pad = L.max_seq - s

    def to_pages(x):
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # [B, n_pages, page, KV, hd] -> strided stripes
        x = x.reshape(bsz, L.n_pages, L.page, kv, hd)
        # page p -> (stripe p % seq_shards, local p // seq_shards)
        x = x.reshape(bsz, L.pages_loc, L.seq_shards, L.page, kv, hd)
        # split kv into shards: worker w = kv_shard * seq_shards + stripe
        x = x.reshape(bsz, L.pages_loc, L.seq_shards, L.page, L.kv_shards,
                      L.kv_loc, hd)
        # -> [B, kv_shards, seq_shards, kv_loc, pages_loc, page, hd]
        x = x.transpose(0, 4, 2, 5, 1, 3, 6)
        return x.reshape(bsz, L.n_workers, L.kv_loc, L.pages_loc, L.page, hd)

    k_pg = to_pages(k)
    v_pg = to_pages(v)
    # embedding-indexed copy: [B, W, kv_loc, hd, S_loc]
    k_emb = k_pg.reshape(bsz, L.n_workers, L.kv_loc, L.seq_loc, hd) \
                .swapaxes(-1, -2)
    if lengths is None:
        v_sum = jnp.sum(v.astype(jnp.float32), axis=1)
    else:
        mask = (jnp.arange(s) < lengths)[None, :, None, None]
        v_sum = jnp.sum(jnp.where(mask, v.astype(jnp.float32), 0.0), axis=1)
    cache = dict(cache)
    cache["k_pages"] = k_pg.astype(cache["k_pages"].dtype)
    cache["v_pages"] = v_pg.astype(cache["v_pages"].dtype)
    cache["k_embed"] = k_emb.astype(cache["k_embed"].dtype)
    cache["v_sum"] = v_sum
    return cache


def local_positions(layout: KVLayout, stripe):
    """Global token positions of a worker's local sequence, [S_loc]."""
    L = layout
    lp = jnp.arange(L.pages_loc, dtype=jnp.int32)
    slot = jnp.arange(L.page, dtype=jnp.int32)
    gp = lp * L.seq_shards + stripe
    return (gp[:, None] * L.page + slot[None, :]).reshape(-1)


def evict_pages(layout: KVLayout, cache: dict, keep_mask) -> dict:
    """FTL-level eviction: retire whole pages from the logical view WITHOUT
    touching stored bytes — a metadata-only update (the reason the FTL owns
    the mapping; zero data movement, zero write amplification).

    keep_mask: [n_pages] bool over GLOBAL logical pages (True = retain).
    Workers mask retired pages' tokens at read time. This is the retention
    hook for context truncation / H2O-style page retirement at the paper's
    page granularity.
    """
    L = layout
    km = jnp.asarray(keep_mask, bool)
    # global page p -> (stripe p % seq_shards, local p // seq_shards);
    # per-worker local view: [W, P_loc]
    stripes = jnp.arange(L.n_pages) % L.seq_shards
    locals_ = jnp.arange(L.n_pages) // L.seq_shards
    per_stripe = jnp.zeros((L.seq_shards, L.pages_loc), bool
                           ).at[stripes, locals_].set(km)
    per_worker = jnp.tile(per_stripe, (L.kv_shards, 1))       # [W, P_loc]
    cache = dict(cache)
    pv = cache.get("page_valid")
    if pv is None:
        b = cache["k_pages"].shape[0]
        pv = jnp.ones((b, L.n_workers, L.kv_loc, L.pages_loc), bool)
    cache["page_valid"] = pv & per_worker[None, :, None, :]
    return cache


def gather_pages(pages, page_idx, block_table=None):
    """Fetch pages by (possibly repeated) logical page index — the FTL read
    path. pages: [..., P, page, hd]; page_idx: [..., n] -> [..., n, page, hd].
    block_table translates logical -> physical first."""
    if block_table is not None:
        page_idx = jnp.take_along_axis(block_table, page_idx, axis=-1)
    return jnp.take_along_axis(
        pages, page_idx[..., None, None], axis=-3)
