"""Gradient compression with error feedback (distributed-optimization trick).

int8 block-quantized gradients around the data-parallel all-reduce:
each leaf is quantized per 256-element block to int8 + f32 scale before the
psum and dequantized after, with a persistent error-feedback buffer so the
quantization error is re-injected next step (convergence-preserving, cf.
1-bit Adam / EF-SGD literature). ~3.5x fewer DP collective bytes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    return jnp.pad(flat, (0, pad)), pad


def quantize(x):
    """-> (int8 values, f32 per-block scales, meta)."""
    flat, pad = _pad_to_block(x.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)),
                 -127, 127).astype(jnp.int8)
    return q, scale[:, 0], (x.shape, pad)


def dequantize(q, scale, meta):
    shape, pad = meta
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def compress_leaf(g, err):
    """Quantize (g + error feedback); return (dequantized g, new error)."""
    g32 = g.astype(jnp.float32) + err
    q, s, meta = quantize(g32)
    g_hat = dequantize(q, s, meta)
    return g_hat, g32 - g_hat


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads, err_state):
    """Apply EF-int8 compression to a gradient pytree. Returns
    (compressed-dequantized grads, new error state).

    Under pjit the psum over the data axis happens on the *quantized*
    representation in a real deployment; here the quantize->dequantize
    round-trip models the numerics exactly while XLA still sees the f32
    all-reduce (bytes accounted analytically in benchmarks/roofline)."""
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err_state)
    outs = [compress_leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in outs]),
            jax.tree.unflatten(tdef, [o[1] for o in outs]))
