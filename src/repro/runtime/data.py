"""Deterministic, shardable synthetic data pipeline.

Stateless addressing: batch contents are a pure function of
(seed, step, global_row) — any host can materialize exactly its rows, so
restart/elastic-rescale never replays or skips data. This is the property a
production loader (e.g. index-shuffled deterministic sampling) provides;
tokens here are synthetic (no datasets ship offline) with a Zipf-ish
marginal and short-range repetition structure so compression-style losses
move during the example runs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # elastic: this host materializes rows [row_start, row_start + rows)
    row_start: int = 0
    rows: Optional[int] = None


def _batch_tokens(dc: DataConfig, step: int) -> np.ndarray:
    rows = dc.rows if dc.rows is not None else dc.global_batch
    rng = np.random.Generator(np.random.Philox(
        key=dc.seed, counter=np.array([step, dc.row_start, 0, 0],
                                      np.uint64)))
    v = dc.vocab_size
    # Zipf-ish marginal over a shuffled alphabet
    base = rng.zipf(1.3, size=(rows, dc.seq_len + 1)) % v
    # short-range structure: repeat previous token with p=0.15
    rep = rng.random((rows, dc.seq_len + 1)) < 0.15
    out = base.copy()
    out[:, 1:] = np.where(rep[:, 1:], out[:, :-1], out[:, 1:])
    return out.astype(np.int32)


def batches(dc: DataConfig, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        toks = _batch_tokens(dc, step)
        yield {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
        step += 1


def batch_at(dc: DataConfig, step: int) -> Dict[str, np.ndarray]:
    toks = _batch_tokens(dc, step)
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


def frontend_stub(dc: DataConfig, cfg, step: int) -> np.ndarray:
    """Precomputed frame/patch embeddings for [audio]/[vlm] archs."""
    rows = dc.rows if dc.rows is not None else dc.global_batch
    rng = np.random.Generator(np.random.Philox(
        key=dc.seed + 1, counter=np.array([step, dc.row_start, 0, 0],
                                          np.uint64)))
    return (rng.standard_normal((rows, cfg.frontend_len, cfg.d_model))
            * 0.02).astype(np.float32)
