"""Fault-tolerant checkpointing: atomic, sharded-aware, keep-last-k.

Layout:
  <dir>/step_000123.tmp/...   (written)
  <dir>/step_000123/          (atomic rename on completion)
    manifest.json             step, tree structure, leaf index
    arr_00000.npy ...         one file per leaf (memory-bounded writes)

Restore places leaves directly onto the target shardings (device_put with
NamedSharding), so a restart onto a *different* mesh (elastic rescale,
node failure) reshards transparently — see runtime/elastic.py.
"""
from __future__ import annotations

import json
import os
import shutil
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _np_dtype(name: str):
    """np.dtype incl. ml_dtypes extension types (bfloat16, fp8, ...)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any, keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(ckpt_dir, name + ".tmp")
    final = os.path.join(ckpt_dir, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    paths, leaves, _ = _flatten_with_paths(tree)
    index = []
    for i, (path, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"arr_{i:05d}.npy"
        if arr.dtype.kind not in "fiub?c":      # extension dtype (bf16, fp8)
            raw = np.frombuffer(arr.tobytes(), np.uint8)
            np.save(os.path.join(tmp, fname), raw)
        else:
            np.save(os.path.join(tmp, fname), arr)
        index.append({"path": path, "file": fname,
                      "dtype": str(arr.dtype), "shape": list(arr.shape)})
    manifest = {"step": step, "time": time.time(), "leaves": index}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)              # atomic publish
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(ckpt_dir, d))


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any,
            shardings: Any = None) -> Any:
    """Restore into the structure of `like`; if `shardings` (a pytree of
    NamedSharding matching `like`) is given, leaves are placed sharded —
    this is the elastic-remesh entry point."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    paths, leaves, treedef = _flatten_with_paths(like)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves))
    out = []
    for path, leaf, shd in zip(paths, leaves, shard_leaves):
        entry = by_path[path]
        arr = np.load(os.path.join(d, entry["file"]))
        dt = _np_dtype(entry["dtype"])
        if arr.dtype != dt:
            arr = np.frombuffer(arr.tobytes(), dt).reshape(entry["shape"])
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out)
