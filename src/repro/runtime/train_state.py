"""Train state + train_step factory: next-token loss, gradient accumulation
over microbatches (lax.scan), optional EF-int8 gradient compression, AdamW /
Adafactor update. Built to be jit-lowered with ShapeDtypeStructs (dry-run)
or executed on real arrays (examples, smoke tests).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.transformer import forward
from repro.runtime import compress as compress_mod
from repro.runtime.optimizer import OptConfig, make_optimizer

AUX_WEIGHT = 0.01


def cross_entropy(logits, targets, vocab_size):
    """Masked CE. targets: int32 [B,S]; ids >= vocab_size or < 0 ignored."""
    valid = (targets >= 0) & (targets < vocab_size)
    tsafe = jnp.where(valid, targets, 0)
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, tsafe[..., None], axis=-1)[..., 0]
    nll = jnp.where(valid, lse - gold, 0.0)
    return jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1)


def init_train_state(cfg, params, oc: OptConfig, compress: bool = False):
    init_fn, _ = make_optimizer(oc)
    state = {"params": params, "opt": init_fn(params),
             "step": jnp.zeros((), jnp.int32)}
    if compress:
        state["err"] = compress_mod.init_error(params)
    return state


def make_train_step(cfg, pol, oc: OptConfig, compress: bool = False,
                    accum_dtype=None):
    """Returns train_step(state, batch) -> (state, metrics)."""
    _, update_fn = make_optimizer(oc)
    n_mb_req = max(cfg.num_microbatches, 1)

    def _n_mb(global_batch: int) -> int:
        """Largest feasible microbatch count <= requested: each microbatch
        must still shard over the data axes."""
        from repro.sharding.policy import NullPolicy, data_size
        dsize = 1 if isinstance(pol, NullPolicy) else data_size(pol.mesh)
        cap = max(global_batch // max(dsize, 1), 1)
        n = min(n_mb_req, cap)
        while global_batch % n or (global_batch // n) % min(dsize, global_batch):
            n -= 1
        return max(n, 1)

    def loss_fn(params, mb):
        logits, aux, _ = forward(cfg, pol, params, mb, "train")
        ce = cross_entropy(logits, mb["targets"], cfg.vocab_size)
        return ce + AUX_WEIGHT * aux, (ce, aux)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        adt = accum_dtype or (jnp.bfloat16 if cfg.param_count() > 100e9
                              else jnp.float32)
        n_mb = _n_mb(batch["tokens"].shape[0])

        if n_mb == 1:
            (loss, (ce, aux)), grads = grad_fn(params, batch)
        else:
            mbs = jax.tree.map(
                lambda a: a.reshape((n_mb, a.shape[0] // n_mb) + a.shape[1:]),
                batch)

            def mb_step(acc, mb):
                (l, (c, a)), g = grad_fn(params, mb)
                acc_g, acc_l, acc_c, acc_a = acc
                acc_g = jax.tree.map(
                    lambda x, y: x + y.astype(x.dtype), acc_g, g)
                return (acc_g, acc_l + l, acc_c + c, acc_a + a), None

            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params)
            (gsum, lsum, csum, asum), _ = jax.lax.scan(
                mb_step, (zero_g, 0.0, 0.0, 0.0), mbs)
            grads = jax.tree.map(lambda g: (g / n_mb).astype(jnp.float32),
                                 gsum)
            loss, ce, aux = lsum / n_mb, csum / n_mb, asum / n_mb

        new_state = dict(state)
        if compress:
            grads, new_state["err"] = compress_mod.compress_grads(
                grads, state["err"])
        new_params, new_opt, gnorm = update_fn(grads, state["opt"], params)
        new_state.update(params=new_params, opt=new_opt,
                         step=state["step"] + 1)
        metrics = {"loss": loss, "ce": ce, "aux": aux, "grad_norm": gnorm}
        return new_state, metrics

    return train_step
