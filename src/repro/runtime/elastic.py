"""Elastic scaling & fault handling.

On node loss the launcher (launch/train.py) calls `remesh`: build the
largest valid mesh from the surviving devices, rebuild the sharding policy,
and restore the last checkpoint directly onto the new shardings. Data
addressing is stateless (runtime/data.py) so no batches are lost or
replayed. Straggler mitigation: `StepWatchdog` flags steps exceeding
k x median; the launcher responds by checkpoint+remesh (the TPU-pod
equivalent of hot-sparing a slow host).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh

from repro.runtime import checkpoint as ckpt_mod


def viable_mesh(devices: Sequence, model_parallelism: int,
                axis_names=("data", "model")) -> Mesh:
    """Largest (data, model) mesh from the surviving devices: model axis is
    fixed (TP degree is a property of the model layout), data axis shrinks
    to the largest multiple that fits."""
    n = len(devices)
    if n < model_parallelism:
        raise RuntimeError(
            f"only {n} devices left; need >= model_parallelism="
            f"{model_parallelism}")
    data = n // model_parallelism
    use = data * model_parallelism
    dev = np.asarray(devices[:use]).reshape(data, model_parallelism)
    return Mesh(dev, axis_names)


def remesh_and_restore(ckpt_dir: str, like_state, new_mesh: Mesh,
                       sharding_fn) -> tuple:
    """Restore the latest checkpoint resharded for `new_mesh`.
    sharding_fn(mesh, like_state) -> pytree of NamedSharding."""
    step = ckpt_mod.latest_step(ckpt_dir)
    if step is None:
        raise RuntimeError(f"no checkpoint in {ckpt_dir}")
    shardings = sharding_fn(new_mesh, like_state)
    state = ckpt_mod.restore(ckpt_dir, step, like_state, shardings)
    return state, step


@dataclass
class StepWatchdog:
    """Flags straggling steps (> factor x rolling median)."""
    factor: float = 3.0
    window: int = 32
    history: List[float] = field(default_factory=list)
    _t0: Optional[float] = None

    def start(self):
        self._t0 = time.monotonic()

    def stop(self) -> bool:
        """Returns True if this step straggled."""
        dt = time.monotonic() - self._t0
        straggled = False
        if len(self.history) >= 8:
            med = float(np.median(self.history[-self.window:]))
            straggled = dt > self.factor * med
        self.history.append(dt)
        return straggled


@dataclass
class FailureSimulator:
    """Deterministic fault injection for integration tests: kills a
    configured set of 'hosts' (device groups) at given steps."""
    fail_at: dict = field(default_factory=dict)   # step -> n_devices_lost

    def surviving(self, devices, step: int):
        lost = sum(v for s, v in self.fail_at.items() if s <= step)
        return devices[:max(len(devices) - lost, 1)]
