"""Optimizers (no external deps): AdamW and Adafactor, with cosine LR
schedule and global-norm clipping.

Adafactor (factored second moment, optional first moment) is the default
for the >=300B architectures: optimizer state is ~O(sqrt) of param count,
which is what makes the 1T-param configs representable per-chip (see
EXPERIMENTS.md §Dry-run memory notes).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"              # adamw | adafactor
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    moment_dtype: str = "float32"    # bf16 halves optimizer HBM


def lr_at(oc: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = oc.lr * (step + 1) / max(oc.warmup_steps, 1)
    t = jnp.clip((step - oc.warmup_steps)
                 / max(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * oc.lr * (1 + jnp.cos(np.pi * t))
    return jnp.where(step < oc.warmup_steps, warm, cos)


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), tree), norm


# ----------------------------------------------------------------------------
# AdamW
# ----------------------------------------------------------------------------

def adamw_init(oc: OptConfig, params):
    dt = jnp.dtype(oc.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(oc: OptConfig, grads, state, params):
    step = state["step"] + 1
    lr = lr_at(oc, step)
    grads, gnorm = clip_by_global_norm(grads, oc.clip_norm)
    t = step.astype(jnp.float32)
    bc1 = 1 - oc.b1 ** t
    bc2 = 1 - oc.b2 ** t

    def upd(g, m, v, p):
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m_new = oc.b1 * m32 + (1 - oc.b1) * g
        v_new = oc.b2 * v32 + (1 - oc.b2) * jnp.square(g)
        update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + oc.eps)
        update = update + oc.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * update
        return (p_new.astype(p.dtype), m_new.astype(m.dtype),
                v_new.astype(v.dtype))

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    outs = [upd(g, m, v, p)
            for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in outs])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in outs])
    return new_params, {"m": new_m, "v": new_v, "step": step}, gnorm


# ----------------------------------------------------------------------------
# Adafactor (factored V, no first moment)
# ----------------------------------------------------------------------------

def _factored(shape):
    return len(shape) >= 2


def adafactor_init(oc: OptConfig, params):
    def init(p):
        if _factored(p.shape):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"v": jax.tree.map(init, params,
                              is_leaf=lambda x: isinstance(x, jax.Array)),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(oc: OptConfig, grads, state, params):
    step = state["step"] + 1
    lr = lr_at(oc, step)
    grads, gnorm = clip_by_global_norm(grads, oc.clip_norm)
    beta2 = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8

    def upd(g, v, p):
        g2 = jnp.square(g) + 1e-30
        if _factored(p.shape):
            vr = beta2 * v["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * v["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            denom = (vr[..., None] * vc[..., None, :]
                     / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True)
                                   [..., None], 1e-30))
            update = g * jax.lax.rsqrt(denom + 1e-30)
            v_new = {"vr": vr, "vc": vc}
        else:
            vv = beta2 * v["v"] + (1 - beta2) * g2
            update = g * jax.lax.rsqrt(vv + 1e-30)
            v_new = {"v": vv}
        # update clipping (Adafactor RMS rule)
        rms = jnp.sqrt(jnp.mean(jnp.square(update)) + 1e-30)
        update = update / jnp.maximum(1.0, rms)
        update = update + oc.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * update
        return p_new.astype(p.dtype), v_new

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_v = tdef.flatten_up_to(state["v"])
    outs = [upd(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_v = jax.tree.unflatten(tdef, [o[1] for o in outs])
    return new_params, {"v": new_v, "step": step}, gnorm


def make_optimizer(oc: OptConfig):
    if oc.name == "adamw":
        return functools.partial(adamw_init, oc), functools.partial(adamw_update, oc)
    if oc.name == "adafactor":
        return (functools.partial(adafactor_init, oc),
                functools.partial(adafactor_update, oc))
    raise ValueError(oc.name)


def default_opt_for(cfg) -> OptConfig:
    big = cfg.param_count() > 100e9
    return OptConfig(name="adafactor" if big else "adamw",
                     moment_dtype="bfloat16" if cfg.param_count() > 10e9
                     else "float32")
