"""kimi-k2-1t-a32b [moe] trillion-param MoE, 384 experts top-8.
[arXiv:2501.kimi2; unverified] (paper-table)"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff=2048,
    vocab_size=163840, n_experts=384, experts_per_token=8,
    num_microbatches=16,
    source="arXiv:2501.kimi2; unverified",
)

SMOKE = FULL.replace(
    name="kimi-k2-1t-a32b-smoke", n_layers=2, d_model=64, n_heads=8,
    n_kv_heads=2, d_ff=64, vocab_size=512, n_experts=8, experts_per_token=2,
    max_seq=128, num_microbatches=1,
)

register(FULL, SMOKE)
