"""minitron-8b [dense] pruned nemotron. [arXiv:2407.14679; hf]"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=16384,
    vocab_size=256000, num_microbatches=4,
    source="arXiv:2407.14679; hf",
)

SMOKE = FULL.replace(
    name="minitron-8b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=512, max_seq=128, num_microbatches=1,
)

register(FULL, SMOKE)
