"""qwen3-moe-30b-a3b [moe] 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_ff=768,
    vocab_size=151936, n_experts=128, experts_per_token=8,
    num_microbatches=4,
    source="hf:Qwen/Qwen3-30B-A3B; hf",
)

SMOKE = FULL.replace(
    name="qwen3-moe-30b-a3b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=96, vocab_size=512, n_experts=8, experts_per_token=2,
    max_seq=128, num_microbatches=1,
)

register(FULL, SMOKE)
