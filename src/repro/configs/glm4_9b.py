"""glm4-9b [dense] RoPE, GQA kv=2 (exercises the seq-sharded KV fallback).
[hf:THUDM/glm-4-9b; hf]"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="glm4-9b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13696,
    vocab_size=151552, num_microbatches=4,
    source="hf:THUDM/glm-4-9b; hf",
)

SMOKE = FULL.replace(
    name="glm4-9b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=1, d_ff=128, vocab_size=512, max_seq=128, num_microbatches=1,
)

register(FULL, SMOKE)
