"""starcoder2-15b [dense] GQA, RoPE. [arXiv:2402.19173; hf]"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4, d_ff=24576,
    vocab_size=49152, num_microbatches=8,
    source="arXiv:2402.19173; hf",
)

SMOKE = FULL.replace(
    name="starcoder2-15b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=512, max_seq=128, num_microbatches=1,
)

register(FULL, SMOKE)
