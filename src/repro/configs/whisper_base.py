"""whisper-base [audio] enc-dec, conv frontend stub.
[arXiv:2212.04356; unverified]"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="whisper-base", family="encdec",
    n_layers=6, n_encoder_layers=6,
    d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
    vocab_size=51865,
    frontend="audio", frontend_len=1500,   # 30s of audio -> 1500 frames
    rope=False, norm="layernorm", tie_embeddings=True,
    source="arXiv:2212.04356; unverified",
)

SMOKE = FULL.replace(
    name="whisper-base-smoke", n_layers=2, n_encoder_layers=2,
    d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=512,
    frontend_len=16, max_seq=128, scan_layers=False,
)

register(FULL, SMOKE)
