"""llava-next-34b [vlm] anyres tiling; backbone only, vision frontend stub.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=20480,
    vocab_size=64000, frontend="vision", frontend_len=576,  # 24x24 patches
    num_microbatches=8,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
)

SMOKE = FULL.replace(
    name="llava-next-34b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=512, frontend_len=8, max_seq=128,
    num_microbatches=1,
)

register(FULL, SMOKE)
