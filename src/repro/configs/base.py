"""Config system: ModelConfig, input-shape registry, arch registry.

Every assigned architecture registers a full-size ModelConfig plus a
reduced smoke-size variant (same family, tiny dims) used by CPU tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclass(frozen=True)
class SparFConfig:
    """Paper Algorithm 1 hyper-parameters (core/sparf.py)."""
    enabled: bool = True
    rank_r: int = 16          # top-r |q| channels for approximate scores
    top_k: int = 256          # tokens kept for the exact attention
    page_tokens: int = 16     # m in Alg.1 — tokens per flash page (token-indexed)
    channel_group: int = 8    # n in Alg.1 — channels per page (embedding-indexed)
    # compression ratio = top_k / seq_len at runtime; r and k are derived from
    # the ratio by SparFConfig.for_ratio when sweeping.

    @staticmethod
    def for_ratio(seq_len: int, ratio: float, head_dim: int,
                  page_tokens: int = 16) -> "SparFConfig":
        """Derive (r, k) from a KV compression ratio, as in the paper's 1/8
        default: k = ratio * seq, r = ratio * head_dim (bandwidth-balanced)."""
        k = max(page_tokens, _round_up(int(seq_len * ratio), page_tokens))
        r = max(1, int(head_dim * ratio * 2))  # SparQ keeps r ~ d/4 at 1/8
        return SparFConfig(rank_r=min(r, head_dim), top_k=min(k, seq_len),
                           page_tokens=page_tokens)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1               # every k-th layer is MoE (hybrid/moe)
    capacity_factor: float = 1.25
    # --- SSM (mamba1) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    dt_rank: int = 0                 # 0 -> d_model // 16
    # --- hybrid (jamba) ---
    attn_period: int = 0             # one attention layer per `attn_period`
    attn_offset: int = 0             # which index within the period is attention
    # --- enc-dec ---
    n_encoder_layers: int = 0
    # --- frontend stub ---
    frontend: str = "none"           # none | audio | vision
    frontend_len: int = 0            # frames/patches produced by the stub
    # --- positional / norm ---
    rope: bool = True
    rope_theta: float = 1e6
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    tie_embeddings: bool = False
    # --- numerics ---
    dtype: str = "bfloat16"
    kv_dtype: str = ""               # "" -> dtype; "float8_e4m3fn" halves
                                     # the decode memory term (beyond-paper)
    ep_mode: str = "auto"            # auto | model | grid (expert layout)
    combine_dtype: str = "float32"   # flash-combine psum precision
    remat_policy: str = "full"       # full | dots (train compute/mem trade)
    # --- runtime ---
    max_seq: int = 1 << 19
    remat: bool = True
    scan_layers: bool = True
    num_microbatches: int = 1        # gradient accumulation for train_step
    sparf: SparFConfig = field(default_factory=SparFConfig)
    attention_impl: str = "insti_sparf"   # dense|insti_dense|insti_sparf|flexgen_like|flexgen_sparq|h2o|local
    source: str = ""                 # provenance tag from the assignment table

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        if self.family == "ssm" or self.family == "hybrid":
            if self.dt_rank == 0:
                object.__setattr__(self, "dt_rank", max(1, self.d_model // 16))

    # ---- derived ----
    @property
    def padded_vocab(self) -> int:
        """Megatron-style vocab padding for 16-way TP divisibility."""
        return _round_up(self.vocab_size, 16 * 8)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def kv_store_dtype(self):
        return jnp.dtype(self.kv_dtype or self.dtype)

    def is_attn_layer(self, i: int) -> bool:
        if self.family == "ssm":
            return False
        if self.family == "hybrid" and self.attn_period:
            return i % self.attn_period == self.attn_offset
        return True

    def is_moe_layer(self, i: int) -> bool:
        return self.n_experts > 0 and (i % self.moe_every == self.moe_every - 1)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), for roofline 6ND."""
        d, f, v = self.d_model, self.d_ff, self.padded_vocab
        hd, h, kv = self.head_dim, self.n_heads, self.n_kv_heads
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d
        mlp = 3 * d * f                      # swiglu
        moe_mlp = self.n_experts * 3 * d * f + d * self.n_experts
        mamba = (d * 2 * self.d_inner + self.d_inner * self.ssm_conv
                 + self.d_inner * (self.dt_rank + 2 * self.ssm_state)
                 + self.dt_rank * self.d_inner + self.d_inner * self.ssm_state
                 + self.d_inner + self.d_inner * d)
        total = v * d * (1 if self.tie_embeddings else 2)
        n_dec = self.n_layers
        for i in range(n_dec):
            if self.family == "ssm" or (self.family == "hybrid" and not self.is_attn_layer(i)):
                total += mamba
            else:
                total += attn
            if self.family in ("ssm",):
                continue                     # mamba1 blocks have no FFN
            total += moe_mlp if self.is_moe_layer(i) else mlp
            total += 2 * d                   # norms
        for _ in range(self.n_encoder_layers):
            total += attn + mlp + 2 * d
        return total

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top-k experts only)."""
        if self.n_experts == 0:
            return self.param_count()
        full = self.param_count()
        f, d = self.d_ff, self.d_model
        n_moe = sum(1 for i in range(self.n_layers) if self.is_moe_layer(i))
        inactive = n_moe * (self.n_experts - self.experts_per_token) * 3 * d * f
        return full - inactive

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                        # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.mode == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# arch registry, populated by the per-arch modules via register()
ARCHS: dict = {}
SMOKE: dict = {}


def register(cfg: ModelConfig, smoke: ModelConfig):
    ARCHS[cfg.name] = cfg
    SMOKE[cfg.name] = smoke
    return cfg


def get_arch(name: str, smoke: bool = False) -> ModelConfig:
    _ensure_loaded()
    table = SMOKE if smoke else ARCHS
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(table)}")
    return table[name]


def list_archs() -> Tuple[str, ...]:
    _ensure_loaded()
    return tuple(sorted(ARCHS))


_LOADED = False


def _ensure_loaded():
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    from repro.configs import (  # noqa: F401
        whisper_base, qwen3_moe_30b_a3b, kimi_k2_1t_a32b, minitron_8b,
        starcoder2_15b, glm4_9b, minitron_4b, falcon_mamba_7b,
        llava_next_34b, jamba_1_5_large_398b, opt13b,
    )
