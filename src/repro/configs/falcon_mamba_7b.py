"""falcon-mamba-7b [ssm] mamba1 arch, attention-free. [arXiv:2410.05355; unverified]

SparF is inapplicable (no KV cache) — see DESIGN.md §Arch-applicability.
The in-storage insight survives as shard-resident SSM state."""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=65024, ssm_state=16, ssm_expand=2, ssm_conv=4,
    rope=False, num_microbatches=4, attention_impl="dense",
    source="arXiv:2410.05355; unverified",
)

SMOKE = FULL.replace(
    name="falcon-mamba-7b-smoke", n_layers=2, d_model=64, vocab_size=512,
    ssm_state=8, max_seq=128, num_microbatches=1, dt_rank=8,
)

register(FULL, SMOKE)
