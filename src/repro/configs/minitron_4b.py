"""minitron-4b [dense] pruned nemotron; 24 heads (head_dim-sharding fallback).
[arXiv:2407.14679; hf]"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="minitron-4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, d_ff=9216,
    vocab_size=256000, num_microbatches=2,
    source="arXiv:2407.14679; hf",
)

SMOKE = FULL.replace(
    name="minitron-4b-smoke", n_layers=2, d_model=48, n_heads=3,
    n_kv_heads=1, d_ff=96, vocab_size=512, max_seq=128, num_microbatches=1,
)

register(FULL, SMOKE)
