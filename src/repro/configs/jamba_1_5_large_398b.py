"""jamba-1.5-large-398b [hybrid] Mamba+attn 1:7 interleave, MoE 16e top-2.
[arXiv:2403.19887; hf]"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=24576,
    vocab_size=65536, n_experts=16, experts_per_token=2, moe_every=2,
    ssm_state=16, ssm_expand=2, ssm_conv=4,
    attn_period=8, attn_offset=3,   # one attention layer per 8, 1:7 ratio
    num_microbatches=16,
    source="arXiv:2403.19887; hf",
)

SMOKE = FULL.replace(
    name="jamba-1.5-large-398b-smoke", n_layers=8, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=512, n_experts=4, experts_per_token=2,
    ssm_state=8, attn_period=4, attn_offset=1, max_seq=128,
    num_microbatches=1, dt_rank=8,
)

register(FULL, SMOKE)
