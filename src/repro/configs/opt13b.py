"""OPT-13B — the paper's own evaluation model (Table/Figs 4-17).
[arXiv:2205.01068; hf]"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="opt13b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=40, d_ff=20480,
    vocab_size=50272, rope=False, norm="layernorm",
    max_seq=2048, num_microbatches=4,
    source="arXiv:2205.01068; hf",
)

SMOKE = FULL.replace(
    name="opt13b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=512, max_seq=128, num_microbatches=1,
)

register(FULL, SMOKE)
