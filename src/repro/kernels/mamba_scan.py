"""Chunked selective-scan Pallas kernel (mamba1 mixer hot loop).

Grid (B, n_chunks): the SSM state h [d_blk, N] lives in VMEM scratch and
carries across the sequential chunk dimension; within a chunk a fori_loop
performs the recurrence entirely in VMEM. d_inner is tiled into lane-sized
blocks so (d_blk, N) stays within VMEM; on real hardware d_blk x N = 512x16
f32 = 32KB per state tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(ab_ref, bx_ref, c_ref, y_ref, h_s, *, chunk, n_chunks):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_s[...] = jnp.zeros_like(h_s)

    ab = ab_ref[0].astype(jnp.float32)          # [chunk, d_blk, N]
    bx = bx_ref[0].astype(jnp.float32)
    c = c_ref[0].astype(jnp.float32)            # [chunk, N]

    def step(t, carry):
        h = carry
        h = ab[t] * h + bx[t]                   # [d_blk, N]
        y = jnp.sum(h * c[t][None, :], axis=-1)  # [d_blk]
        y_ref[0, t] = y.astype(y_ref.dtype)
        return h

    h_s[...] = jax.lax.fori_loop(0, chunk, step, h_s[...])


def mamba_scan(a_bar, bx, c_t, *, chunk=64, d_block=None, interpret=True):
    """a_bar, bx: [B, T, D, N]; c_t: [B, T, N] -> y [B, T, D] f32.

    D is processed per-kernel-call in lane blocks (vmapped outside for
    simplicity; the BlockSpec carves T into chunks)."""
    b, t, d, n = a_bar.shape
    chunk = min(chunk, t)
    assert t % chunk == 0
    n_chunks = t // chunk

    kernel = functools.partial(_scan_kernel, chunk=chunk, n_chunks=n_chunks)
    out = pl.pallas_call(
        kernel,
        grid=(b, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, d, n), lambda b_, c_: (b_, c_, 0, 0)),
            pl.BlockSpec((1, chunk, d, n), lambda b_, c_: (b_, c_, 0, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, c_: (b_, c_, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, d), lambda b_, c_: (b_, c_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, t, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((d, n), jnp.float32)],
        interpret=interpret,
    )(a_bar, bx, c_t)
    return out
