"""Pure-jnp oracles for every Pallas kernel (the allclose references).

These share math with the framework paths (models/layers.py, core/sparf.py)
but are standalone so kernel tests do not depend on framework plumbing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


# ---- flash_attention oracle -------------------------------------------------

def flash_attention(q, k, v, causal=True):
    """q: [B,H,Sq,hd], k/v: [B,H,Sk,hd] -> [B,H,Sq,hd]."""
    hd = q.shape[-1]
    s = jnp.einsum("bhqk,bhck->bhqc", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(hd)
    if causal:
        sq, sk = q.shape[2], k.shape[2]
        mask = (jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None]
                + (sk - sq))
        s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqc,bhck->bhqk", p,
                      v.astype(jnp.float32)).astype(q.dtype)


# ---- paged_attention oracle -------------------------------------------------

def paged_attention(q, k_pages, v_pages, block_table, length):
    """Dense decode attention over a paged store.

    q: [B, KV, G, hd]; k_pages/v_pages: [B, KV, P, page, hd];
    block_table: [B, KV, P] int32 logical->physical; length: int.
    """
    b, kv, p, page, hd = k_pages.shape
    k = jnp.take_along_axis(k_pages, block_table[..., None, None], axis=2)
    v = jnp.take_along_axis(v_pages, block_table[..., None, None], axis=2)
    k = k.reshape(b, kv, p * page, hd)
    v = v.reshape(b, kv, p * page, hd)
    s = jnp.einsum("bkgh,bksh->bkgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(hd)
    valid = jnp.arange(p * page) < length
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgs,bksh->bkgh", pr,
                      v.astype(jnp.float32)).astype(q.dtype)


# ---- sparf oracles ----------------------------------------------------------

def sparf_approx_scores(q_r, chan_idx, k_embed, length):
    """Step 2-4 of Alg.1 (pre-softmax logits).

    q_r: [B,KV,G,r] selected |q| values; chan_idx: [B,KV,G,r] int32;
    k_embed: [B,KV,hd,S]. Returns logits [B,KV,G,S] with dead tokens at
    NEG_INF (temperature applied by caller)."""
    k_r = jnp.take_along_axis(k_embed[:, :, None].astype(jnp.float32),
                              chan_idx[..., None], axis=3)   # [B,KV,G,r,S]
    s_hat = jnp.einsum("bkgr,bkgrs->bkgs", q_r.astype(jnp.float32), k_r)
    s = k_embed.shape[-1]
    return jnp.where((jnp.arange(s) < length)[None, None, None], s_hat,
                     NEG_INF)


def sparf_selected_attention(q, k_pages, v_pages, block_table, tok_idx,
                             sel_valid):
    """Steps 8-10: exact attention over selected tokens, page-granular fetch
    + slot filter. q: [B,KV,G,hd]; tok_idx: [B,KV,G,ksel] (logical token
    ids); sel_valid: [B,KV,G,ksel] bool. Returns (out [B,KV,G,hd] f32,
    m [B,KV,G], l [B,KV,G])."""
    b, kv, p, page, hd = k_pages.shape
    page_idx = tok_idx // page
    slot_idx = tok_idx % page
    bt = jnp.broadcast_to(block_table[:, :, None],
                          page_idx.shape[:3] + (p,))
    phys = jnp.take_along_axis(bt, page_idx, axis=-1)
    def fetch(pages):
        x = jnp.broadcast_to(pages[:, :, None],
                             (b, kv, q.shape[2]) + pages.shape[2:])
        x = jnp.take_along_axis(x, phys[..., None, None], axis=3)
        return jnp.take_along_axis(
            x, slot_idx[..., None, None], axis=-2)[..., 0, :]
    k_sel = fetch(k_pages)
    v_sel = fetch(v_pages)
    logits = jnp.einsum("bkgh,bkgsh->bkgs", q.astype(jnp.float32),
                        k_sel.astype(jnp.float32)) / np.sqrt(hd)
    logits = jnp.where(sel_valid, logits, NEG_INF)
    m = jnp.max(logits, axis=-1)
    pr = jnp.where(sel_valid, jnp.exp(logits - m[..., None]), 0.0)
    l = jnp.sum(pr, axis=-1)
    out = jnp.einsum("bkgs,bkgsh->bkgh", pr, v_sel.astype(jnp.float32))
    return out / jnp.maximum(l, 1e-20)[..., None], m, l


# ---- mamba_scan oracle ------------------------------------------------------

def mamba_scan(a_bar, bx, c_t, h0=None):
    """Selective scan. a_bar, bx: [B,T,D,N]; c_t: [B,T,N]; h0: [B,D,N].
    Returns y [B,T,D] f32 and final h."""
    b, t, d, n = a_bar.shape
    h = jnp.zeros((b, d, n), jnp.float32) if h0 is None else h0

    def step(h, args):
        ab, bxt, ct = args
        h = ab * h + bxt
        return h, jnp.einsum("bdn,bn->bd", h, ct)

    h, ys = jax.lax.scan(step, h, (a_bar.swapaxes(0, 1), bx.swapaxes(0, 1),
                                   c_t.swapaxes(0, 1)))
    return ys.swapaxes(0, 1), h
