"""Tiled causal flash-attention Pallas kernel (prefill / training).

Grid (B, H, nq, nk), innermost nk sequential: online-softmax statistics
(m, l, acc) live in VMEM scratch across the nk dimension; the output block
is written once at the last nk step. Causal block-skipping zeroes the work
above the diagonal. Block shapes default to (bq, bk) = (128, 128) with hd
lanes — MXU-aligned (multiples of (8,128) tiles for bf16/f32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
                  scale, causal, bq, bk, nk, offset):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    run = True
    if causal:
        # block fully above the (offset) diagonal: skip.
        # offset = Sk - Sq aligns the causal diagonal to the sequence end
        # when the query block is a suffix of the keys (decode prefix case)
        run = ki * bk <= qi * bq + bq - 1 + offset

    @pl.when(run if causal else True)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)          # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)          # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = (offset + qi * bq
                    + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0))
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_s[...] = l_s[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_s[...] = acc_s[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_s[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_s[...]
                       / jnp.maximum(l_s[...], 1e-20)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, bq=128, bk=128,
                    interpret=True):
    """q: [B,H,Sq,hd]; k,v: [B,H,Sk,hd] -> [B,H,Sq,hd]."""
    b, h, sq, hd = q.shape
    sk = k.shape[2]
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    nq, nk = sq // bq, sk // bk
    scale = 1.0 / np.sqrt(hd)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               bq=bq, bk=bk, nk=nk, offset=sk - sq)
    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b_, h_, q_, k_: (b_, h_, k_, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b_, h_, q_, k_: (b_, h_, k_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
