"""Paged decode attention Pallas kernel (InstI-Dense on one worker).

The FTL lives in the index_map: the block table is passed through
PrefetchScalarGridSpec, and each grid step's K/V page DMA is addressed by
`block_table[b, kv, i]` — logical->physical translation happens *before*
the HBM->VMEM copy, exactly the role of InstInfer's FTL, and every copy is
one whole page (page-granular access discipline).

Grid (B, KV, n_pages); online-softmax scratch carries across pages; the
G query heads of a kv head are processed together (GQA: q block [G, hd]).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_s, l_s, acc_s, *, page, n_pages):
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    length = len_ref[0]
    # page is live iff its first position < length (logical index!)
    @pl.when(pi * page < length)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)              # [G, hd]
        k = k_ref[0, 0, 0].astype(jnp.float32)           # [page, hd]
        v = v_ref[0, 0, 0].astype(jnp.float32)
        hd = q.shape[-1]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s / np.sqrt(hd)                              # [G, page]
        pos = pi * page + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(pos < length, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_s[...] = l_s[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_s[...] = acc_s[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_s[...] = m_new

    @pl.when(pi == n_pages - 1)
    def _finalize():
        o_ref[0, 0] = (acc_s[...]
                       / jnp.maximum(l_s[...], 1e-20)).astype(o_ref.dtype)


def paged_attention(q, k_pages, v_pages, block_table, length, *,
                    interpret=True):
    """q: [B, KV, G, hd]; k_pages/v_pages: [B, KV, P, page, hd];
    block_table: [B, KV, P] int32; length: scalar int32.
    Returns [B, KV, G, hd]."""
    b, kv, g, hd = q.shape
    _, _, n_pages, page, _ = k_pages.shape
    length = jnp.asarray(length, jnp.int32).reshape(1)

    kernel = functools.partial(_paged_kernel, page=page, n_pages=n_pages)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                # block_table, length
        grid=(b, kv, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd),
                         lambda b_, k_, p_, bt, ln: (b_, k_, 0, 0)),
            # FTL translation: fetch physical page bt[b, kv, p]
            pl.BlockSpec((1, 1, 1, page, hd),
                         lambda b_, k_, p_, bt, ln:
                         (b_, k_, bt[b_, k_, p_], 0, 0)),
            pl.BlockSpec((1, 1, 1, page, hd),
                         lambda b_, k_, p_, bt, ln:
                         (b_, k_, bt[b_, k_, p_], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda b_, k_, p_, bt, ln: (b_, k_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), q.dtype),
        interpret=interpret,
    )(block_table, length, q, k_pages, v_pages)
