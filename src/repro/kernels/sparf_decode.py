"""SparF decode kernels — the in-storage attention engine of InstCSD,
realized as two Pallas kernels around a host-side top-k (the argtopk unit):

  1. `approx_scores`  — steps 2-4 of Alg.1: gathers the top-r K *channels*
     from the embedding-indexed copy. The channel index is scalar-prefetched
     and applied in the index_map, so each grid step DMAs exactly one
     channel row (a contiguous [1, S] lane read — why K is stored twice).
  2. `selected_attention` — steps 8-10: gathers the top-k tokens' *pages*
     (block-table translation in the index_map = FTL) and applies the
     in-VMEM slot filter (the NFC filter) before the exact softmax.

The dual-step load is structural: step 2's DMA is page/row-granular, the
weak elements are discarded only after they are in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


# ----------------------------------------------------------------------------
# kernel 1: approximate scores from top-r channels
# ----------------------------------------------------------------------------

def _approx_kernel(chan_ref, qr_ref, ke_ref, s_ref, acc_s, *, r):
    ri = pl.program_id(3)

    @pl.when(ri == 0)
    def _init():
        acc_s[...] = jnp.zeros_like(acc_s)

    qv = qr_ref[0, 0, 0, ri]                          # scalar q_r value
    krow = ke_ref[0, 0, 0].astype(jnp.float32)        # [1, S] channel row
    acc_s[...] += qv.astype(jnp.float32) * krow

    @pl.when(ri == r - 1)
    def _finalize():
        s_ref[0, 0, 0] = acc_s[0]


def approx_scores(q_r, chan_idx, k_embed, *, interpret=True):
    """q_r: [B,KV,G,r] (selected q values); chan_idx: [B,KV,G,r] int32;
    k_embed: [B,KV,hd,S]. Returns pre-temperature logits [B,KV,G,S] f32.
    Masking/temperature are applied by the caller (ops.sparf_attention)."""
    b, kv, g, r = q_r.shape
    s = k_embed.shape[-1]

    kernel = functools.partial(_approx_kernel, r=r)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                 # chan_idx
        grid=(b, kv, g, r),
        in_specs=[
            pl.BlockSpec((1, 1, 1, r),
                         lambda b_, k_, g_, r_, ci: (b_, k_, g_, 0)),
            # channel gather: DMA one embedding-indexed row per step
            pl.BlockSpec((1, 1, 1, s),
                         lambda b_, k_, g_, r_, ci:
                         (b_, k_, ci[b_, k_, g_, r_], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, s),
                               lambda b_, k_, g_, r_, ci: (b_, k_, g_, 0)),
        scratch_shapes=[pltpu.VMEM((1, s), jnp.float32)],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, g, s), jnp.float32),
        interpret=interpret,
    )(chan_idx, q_r, k_embed)


# ----------------------------------------------------------------------------
# kernel 2: exact attention over the selected tokens (page fetch + filter)
# ----------------------------------------------------------------------------

def _selected_kernel(pidx_ref, slot_ref, valid_ref, q_ref, k_ref, v_ref,
                     o_ref, m_ref, l_ref, acc_s, m_s, l_s, *, ksel, page):
    si = pl.program_id(3)

    @pl.when(si == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0].astype(jnp.float32)               # [1, hd]
    kpage = k_ref[0, 0, 0].astype(jnp.float32)        # [page, hd]
    vpage = v_ref[0, 0, 0].astype(jnp.float32)
    hd = q.shape[-1]
    slot = slot_ref[pl.program_id(0), pl.program_id(1), pl.program_id(2), si]
    ok = valid_ref[pl.program_id(0), pl.program_id(1), pl.program_id(2), si]
    # NFC filter: only the selected slot of the fetched page survives
    srow = jax.lax.dot_general(q, kpage, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)[0]
    logit = jnp.where(ok != 0, srow[slot] / np.sqrt(hd), NEG_INF)
    vtok = vpage[slot][None, :]                        # [1, hd]
    m_prev = m_s[0, 0]
    m_new = jnp.maximum(m_prev, logit)
    p = jnp.where(ok != 0, jnp.exp(logit - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + p
    acc_s[...] = acc_s[...] * corr + p * vtok
    m_s[0, 0] = m_new

    @pl.when(si == ksel - 1)
    def _finalize():
        o_ref[0, 0, 0] = acc_s[0]
        m_ref[0, 0, 0] = m_s[0, 0]
        l_ref[0, 0, 0] = l_s[0, 0]


def selected_attention(q, k_pages, v_pages, block_table, tok_idx, sel_valid,
                       *, interpret=True):
    """q: [B,KV,G,hd]; k_pages/v_pages: [B,KV,P,page,hd];
    block_table: [B,KV,P]; tok_idx: [B,KV,G,ksel]; sel_valid same bool.
    Returns (num [B,KV,G,hd] f32 — UNNORMALIZED exp-weighted sum at max m,
    m [B,KV,G], l [B,KV,G]) for the cross-worker flash combine."""
    b, kv, g, hd = q.shape
    _, _, n_pages, page, _ = k_pages.shape
    ksel = tok_idx.shape[-1]
    page_idx = (tok_idx // page).astype(jnp.int32)
    slot_idx = (tok_idx % page).astype(jnp.int32)
    valid = sel_valid.astype(jnp.int32)

    kernel = functools.partial(_selected_kernel, ksel=ksel, page=page)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,                 # page_idx, slot_idx, valid
        grid=(b, kv, g, ksel),
        in_specs=[
            pl.BlockSpec((1, 1, 1, hd),
                         lambda b_, k_, g_, s_, pi, sl, va:
                         (b_, k_, g_, 0)),
            # page fetch with FTL translation (note: pi already logical;
            # block_table translation is folded in by the wrapper)
            pl.BlockSpec((1, 1, 1, page, hd),
                         lambda b_, k_, g_, s_, pi, sl, va:
                         (b_, k_, pi[b_, k_, g_, s_], 0, 0)),
            pl.BlockSpec((1, 1, 1, page, hd),
                         lambda b_, k_, g_, s_, pi, sl, va:
                         (b_, k_, pi[b_, k_, g_, s_], 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, hd),
                         lambda b_, k_, g_, s_, pi, sl, va: (b_, k_, g_, 0)),
            pl.BlockSpec((1, 1, 1),
                         lambda b_, k_, g_, s_, pi, sl, va: (b_, k_, g_)),
            pl.BlockSpec((1, 1, 1),
                         lambda b_, k_, g_, s_, pi, sl, va: (b_, k_, g_)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
    )
    # fold the FTL translation into the prefetched indices
    phys_idx = jnp.take_along_axis(
        jnp.broadcast_to(block_table[:, :, None], (b, kv, g, n_pages)),
        page_idx, axis=-1).astype(jnp.int32)
    num, m, l = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, kv, g, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, kv, g), jnp.float32),
            jax.ShapeDtypeStruct((b, kv, g), jnp.float32),
        ],
        interpret=interpret,
    )(phys_idx, slot_idx, valid, q, k_pages, v_pages)
    return num, m, l
