"""jit'd wrappers over the Pallas kernels, plus the composed SparF op
(kernel-1 -> host argtopk -> kernel-2 -> mean-V compensation), matching
core/sparf.py math. On CPU these run with interpret=True; on TPU set
REPRO_PALLAS_COMPILE=1 (or pass interpret=False).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import flash_attention as _fa
from repro.kernels import mamba_scan as _ms
from repro.kernels import paged_attention as _pa
from repro.kernels import sparf_decode as _sd

NEG_INF = -1e30


def _interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk"))
def flash_attention(q, k, v, causal=True, bq=128, bk=128):
    return _fa.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                               interpret=_interpret())


@jax.jit
def paged_attention(q, k_pages, v_pages, block_table, length):
    return _pa.paged_attention(q, k_pages, v_pages, block_table, length,
                               interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("rank_r", "top_k"))
def sparf_attention(q, k_pages, v_pages, k_embed, block_table, v_sum,
                    length, rank_r: int, top_k: int):
    """Full SparF Alg.1 on one worker via the two kernels.

    q: [B,KV,G,hd]; k_pages/v_pages: [B,KV,P,page,hd];
    k_embed: [B,KV,hd,S]; v_sum: [B,KV,hd] f32. Returns [B,KV,G,hd] f32.
    """
    b, kv, g, hd = q.shape
    s = k_embed.shape[-1]
    page = k_pages.shape[-2]
    qf = q.astype(jnp.float32)
    r = min(rank_r, hd)
    ksel = min(top_k, s)

    # step 1 (argtopk unit): top-r channels of |q|
    _, chan_idx = jax.lax.top_k(jnp.abs(qf), r)
    q_r = jnp.take_along_axis(qf, chan_idx, axis=-1)

    # steps 2-4 (kernel 1): channel-row gather + approximate logits
    s_hat = _sd.approx_scores(q_r, chan_idx.astype(jnp.int32), k_embed,
                              interpret=_interpret())
    l1 = (jnp.sum(jnp.abs(q_r), -1)
          / jnp.maximum(jnp.sum(jnp.abs(qf), -1), 1e-20))
    temp = jnp.sqrt(hd * jnp.maximum(l1, 1e-20))
    s_hat = s_hat / temp[..., None]
    s_hat = jnp.where((jnp.arange(s) < length)[None, None, None], s_hat,
                      NEG_INF)

    # steps 5-7 (argtopk unit): token selection + alpha mass
    top_vals, tok_idx = jax.lax.top_k(s_hat, ksel)
    sel_valid = top_vals > NEG_INF / 2
    m_hat = jnp.max(s_hat, axis=-1)
    e_all = jnp.where((jnp.arange(s) < length)[None, None, None],
                      jnp.exp(s_hat - m_hat[..., None]), 0.0)
    alpha = (jnp.sum(jnp.where(sel_valid,
                               jnp.exp(top_vals - m_hat[..., None]), 0.0), -1)
             / jnp.maximum(jnp.sum(e_all, -1), 1e-20))

    # steps 8-10 (kernel 2): page fetch + NFC filter + exact softmax
    num, m, l = _sd.selected_attention(
        q, k_pages, v_pages, block_table, tok_idx.astype(jnp.int32),
        sel_valid, interpret=_interpret())
    out_exact = num / jnp.maximum(l, 1e-20)[..., None]

    # step 11: mean-V compensation
    v_mean = v_sum / jnp.maximum(length, 1).astype(jnp.float32)
    alpha = jnp.clip(alpha, 0.0, 1.0)[..., None]
    return alpha * out_exact + (1 - alpha) * v_mean[:, :, None, :]


@functools.partial(jax.jit, static_argnames=("chunk",))
def mamba_scan(a_bar, bx, c_t, chunk=64):
    return _ms.mamba_scan(a_bar, bx, c_t, chunk=chunk,
                          interpret=_interpret())
