"""HLO text analysis: collective-bytes accounting for the roofline.

cost_analysis() does not report collective traffic, so we parse the
post-SPMD optimized HLO (compiled.as_text()) and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Optimized HLO prints operands untyped (`%name`), so operand bytes are
derived from the RESULT shape and the replica-group size:
  all-reduce / all-to-all / collective-permute : operand == result
  all-gather    : operand = result / participants
  reduce-scatter: operand = result * participants
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_RESULT_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|([a-z0-9]+)\[([0-9,]*)\](?:\{[^}]*\})?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_ILOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _participants(line: str) -> int:
    m = _GROUPS_ILOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def _parse_line(line: str):
    m = _RESULT_RE.search(line)
    if m is None:
        return None
    tuple_body, dtype, dims, op, start = m.groups()
    if tuple_body is not None:
        total = sum(shape_bytes(d, dm)
                    for d, dm in _SHAPE_RE.findall(tuple_body))
    else:
        total = shape_bytes(dtype, dims)
    return op, total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes per collective kind (+ 'total')."""
    out: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        parsed = _parse_line(line)
        if parsed is None:
            continue
        op, result_bytes = parsed
        p = _participants(line)
        if op == "all-gather":
            operand = result_bytes // max(p, 1)
        elif op == "reduce-scatter":
            operand = result_bytes * p
        else:
            operand = result_bytes
        out[op] += operand
        out[op + "_wire"] = out.get(op + "_wire", 0) + (
            operand * (p - 1) if op in ("all-gather", "all-reduce")
            else operand)
    out["total"] = sum(v for k, v in out.items()
                       if k in ("all-gather", "all-reduce", "reduce-scatter",
                                "all-to-all", "collective-permute"))
    return dict(out)


_CONVERT_RE = re.compile(
    r"=\s*f32\[([0-9,]*)\](?:\{[^}]*\})?\s*convert\(")


def convert_bytes(hlo_text: str) -> int:
    """f32 result bytes of convert ops. The CPU backend converts bf16 dot
    operands to f32 (no native bf16 matmul), inflating 'bytes accessed' by
    ~3x for weight-streaming ops; TPU executes these natively. Roofline
    reports a TPU-adjusted memory term = bytes - 2 * convert_bytes
    (the f32 write + f32 re-read that do not exist on TPU)."""
    total = 0
    for line in hlo_text.splitlines():
        m = _CONVERT_RE.search(line)
        if m:
            n = 1
            for d in m.group(1).split(","):
                if d:
                    n *= int(d)
            total += n * 4
    return total


def collective_counts(hlo_text: str) -> Dict[str, int]:
    out: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        parsed = _parse_line(line)
        if parsed:
            out[parsed[0]] += 1
    return dict(out)
