"""Model zoo: build any assigned architecture by name, init params, and
produce ShapeDtypeStruct input specs for every (arch x input-shape) cell.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig, get_arch
from repro.models.transformer import forward, init_cache, init_params


def build(name: str, smoke: bool = False) -> ModelConfig:
    return get_arch(name, smoke=smoke)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a step function
    (no device allocation) — the dry-run contract.

    train/prefill: {"tokens": [B, S], (+frontend stub embeddings)}
    decode       : {"token": [B, 1]} (the KV cache is a separate arg)
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = cfg.activation_dtype
    if shape.mode == "decode":
        specs = {"token": jax.ShapeDtypeStruct((b, 1), i32)}
    else:
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if shape.mode == "train":
            specs["targets"] = jax.ShapeDtypeStruct((b, s), i32)
    if cfg.frontend == "audio" and shape.mode != "decode":
        specs["frames"] = jax.ShapeDtypeStruct((b, cfg.frontend_len,
                                                cfg.d_model), dt)
    if cfg.frontend == "vision" and shape.mode != "decode":
        specs["patches"] = jax.ShapeDtypeStruct((b, cfg.frontend_len,
                                                 cfg.d_model), dt)
    return specs


def make_inputs(cfg: ModelConfig, shape: ShapeConfig, key) -> Dict[str, jax.Array]:
    """Concrete random inputs matching input_specs (smoke tests/examples)."""
    specs = input_specs(cfg, shape)
    out = {}
    for name, spec in specs.items():
        key, sub = jax.random.split(key)
        if spec.dtype == jnp.int32:
            out[name] = jax.random.randint(sub, spec.shape, 0,
                                           cfg.vocab_size, jnp.int32)
        else:
            out[name] = (jax.random.normal(sub, spec.shape, jnp.float32)
                         * 0.02).astype(spec.dtype)
    return out


__all__ = ["build", "forward", "init_params", "init_cache", "input_specs",
           "make_inputs"]
