"""Mixture-of-Experts FFN: token-choice top-k routing with per-shard
capacity, expert parallelism over the `model` mesh axis.

Dispatch strategy (see DESIGN.md): routing runs inside a shard_map over
(data, model). Each (data, model) cell routes its local tokens, builds a
capacity buffer for *its own* expert shard only, runs the expert GeMMs, and
scatters partial token outputs; a single psum over `model` combines — the
EP collective cost is one activation-sized all-reduce per MoE layer, with
no [T, E, C] one-hot dispatch tensor ever materialized.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.layers import _init
from repro.sharding.policy import NullPolicy, data_axes


def moe_init(key, d, d_ff, n_experts, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": _init(k1, (d, n_experts), jnp.float32, scale=0.02),
        "w_gate": _init(k2, (n_experts, d, d_ff), dtype),
        "w_up": _init(k3, (n_experts, d, d_ff), dtype),
        "w_down": _init(k4, (n_experts, d_ff, d), dtype),
    }


def _route(x2d, router, k):
    """x2d: [T, d] -> (gates [T,k], experts [T,k] int32, aux losses)."""
    logits = x2d.astype(jnp.float32) @ router            # [T, E]
    probs = jax.nn.softmax(logits, -1)
    gates, experts = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch-style)
    e = router.shape[-1]
    me = jnp.mean(jax.nn.one_hot(experts[:, 0], e), axis=0)
    ce = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(me * ce)
    return gates, experts, aux


def _capacity(t_tokens, n_experts, k, cf):
    c = int(np.ceil(k * t_tokens / n_experts * cf))
    return max(min(t_tokens, max(c, 4)), 1)


def _expert_ffn(w_gate, w_up, w_down, xb):
    """xb: [E_loc, C, d] -> [E_loc, C, d] (swiglu)."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xb, w_gate))
    h = h * jnp.einsum("ecd,edf->ecf", xb, w_up)
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def _moe_local(router, wg, wu, wd, x2d, k, cf, e_start, n_experts):
    """Route local tokens against the GLOBAL expert ids; compute only the
    experts held locally in wg/wu/wd ([E_loc, ...], global range
    [e_start, e_start + E_loc)). Returns the partial output [T, d]
    (zeros for tokens routed to other shards) and the aux loss."""
    t, d = x2d.shape
    e_count = wg.shape[0]
    gates, experts, aux = _route(x2d, router, k)
    cap = _capacity(t, n_experts, k, cf)
    flat_e = experts.reshape(-1)                          # [T*k]
    flat_g = gates.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    # position of each assignment within its expert's capacity buffer,
    # via a stable sort (no [T*k, E] one-hot is ever materialized)
    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    first = jnp.searchsorted(se, se, side="left")
    pos_sorted = jnp.arange(se.shape[0], dtype=jnp.int32) - first.astype(jnp.int32)
    pos = jnp.zeros_like(flat_e).at[order].set(pos_sorted)  # [T*k]
    local = (flat_e >= e_start) & (flat_e < e_start + e_count) & (pos < cap)
    le = jnp.where(local, flat_e - e_start, 0).reshape(t, k)
    lp = jnp.where(local, pos, cap).reshape(t, k)         # cap = dump slot
    localk = local.reshape(t, k)
    gk = flat_g.reshape(t, k)
    # dispatch: [E_loc, cap+1, d]; loop over the k slots so no [T*k, d]
    # intermediate is ever materialized
    buf = jnp.zeros((e_count, cap + 1, d), x2d.dtype)
    for j in range(k):
        buf = buf.at[le[:, j], lp[:, j]].add(
            jnp.where(localk[:, j, None], x2d, 0))
    out_b = _expert_ffn(wg, wu, wd, buf[:, :cap])
    # combine: gather each slot's expert output, weight, accumulate
    out = jnp.zeros((t, d), x2d.dtype)
    for j in range(k):
        got = out_b[le[:, j], jnp.minimum(lp[:, j], cap - 1)]
        out = out + jnp.where(localk[:, j, None],
                              got * gk[:, j, None].astype(x2d.dtype), 0)
    return out, aux


# per-shard token threshold above which grid EP uses all-to-all dispatch
A2A_MIN_TOKENS = 1024


def _dsize(pol):
    from repro.sharding.policy import data_size
    return data_size(pol.mesh)


def _positions_by(key_ids):
    """Position of each element within its key's segment (stable sort)."""
    order = jnp.argsort(key_ids, stable=True)
    sk = key_ids[order]
    first = jnp.searchsorted(sk, sk, side="left")
    pos_sorted = jnp.arange(sk.shape[0], dtype=jnp.int32) - first.astype(jnp.int32)
    return jnp.zeros_like(key_ids).at[order].set(pos_sorted)


def _grid_a2a(pol, xl, router, wg, wu, wd, k, cf, e, e_loc, n_data, d):
    """All-to-all grid-EP dispatch (runs inside shard_map over data x model).

    1. route LOCAL tokens; destination shard of assignment = expert // e_loc
    2. pack per-destination capacity buffers (x, expert id, token id, gate)
    3. all_to_all over `data`: each cell receives its experts' tokens
    4. local expert FFN via capacity buffers (f sharded over `model`)
    5. all_to_all back; combine into local tokens; psum partials over model
    """
    bl, sl, _ = xl.shape
    x2d = xl.reshape(-1, d)
    t = x2d.shape[0]
    d_idx = jax.lax.axis_index("data")
    gates, experts, aux = _route(x2d, router, k)
    flat_e = experts.reshape(-1)                    # [T*k]
    flat_g = gates.reshape(-1).astype(x2d.dtype)
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    dest = flat_e // e_loc                          # [T*k] in [0, n_data)
    # a destination shard can receive up to t*k assignments (not t)
    c_send = max(min(t * k, max(int(np.ceil(k * t / n_data * cf)), 4)), 1)
    pos = _positions_by(dest)
    ok = pos < c_send
    dst = jnp.where(ok, dest, 0)
    slot = jnp.where(ok, pos, c_send)
    # pack: [n_data, c_send(+1 dump), d] + int meta (expert, token) + gate
    xbuf = jnp.zeros((n_data, c_send + 1, d), x2d.dtype)
    xbuf = xbuf.at[dst, slot].set(jnp.where(ok[:, None], x2d[flat_tok], 0))
    meta_e = jnp.full((n_data, c_send + 1), -1, jnp.int32).at[dst, slot].set(
        jnp.where(ok, flat_e, -1))
    meta_g = jnp.zeros((n_data, c_send + 1), x2d.dtype).at[dst, slot].set(
        jnp.where(ok, flat_g, 0))
    # ---- dispatch over the wire ----
    xr = jax.lax.all_to_all(xbuf[:, :c_send], "data", 0, 0, tiled=False)
    er = jax.lax.all_to_all(meta_e[:, :c_send], "data", 0, 0, tiled=False)
    # received: [n_src, c_send, ...] tokens destined to MY experts
    xr2 = xr.reshape(-1, d)
    er2 = er.reshape(-1)
    le = jnp.where(er2 >= 0, er2 - d_idx * e_loc, 0)
    cap_e = _capacity(xr2.shape[0], e_loc, 1, cf)
    pe = _positions_by(jnp.where(er2 >= 0, le, e_loc))
    ok_e = (er2 >= 0) & (pe < cap_e)
    le_s = jnp.where(ok_e, le, 0)
    pe_s = jnp.where(ok_e, pe, cap_e)
    ebuf = jnp.zeros((e_loc, cap_e + 1, d), x2d.dtype)
    ebuf = ebuf.at[le_s, pe_s].set(jnp.where(ok_e[:, None], xr2, 0))
    out_b = _expert_ffn(wg, wu, wd, ebuf[:, :cap_e])
    # scatter expert outputs back to received slots (f-partial over model)
    yr2 = jnp.where(ok_e[:, None],
                    out_b[le_s, jnp.minimum(pe_s, cap_e - 1)], 0)
    yr = yr2.reshape(n_data, c_send, d)
    # ---- return over the wire ----
    yback = jax.lax.all_to_all(yr, "data", 0, 0, tiled=False)
    ypad = jnp.concatenate(
        [yback, jnp.zeros((n_data, 1, d), yback.dtype)], axis=1)
    got = ypad[dst, jnp.where(ok, slot, c_send)]    # [T*k, d]
    contrib = jnp.where(ok[:, None], got * meta_g[dst, slot][:, None], 0)
    out = jnp.zeros((t, d), x2d.dtype).at[flat_tok].add(contrib)
    out = jax.lax.psum(out, "model")                # f-contraction partials
    aux = jax.lax.pmean(aux, ("data", "model"))
    return out.reshape(bl, sl, d), aux


def apply_moe(cfg, pol, p, x):
    """x: [B, S, d] -> [B, S, d]. EP over the model axis when on-mesh."""
    b, s, d = x.shape
    e, k, cf = cfg.n_experts, cfg.experts_per_token, cfg.capacity_factor
    if isinstance(pol, NullPolicy):
        out, aux = _moe_local(p["router"], p["w_gate"], p["w_up"],
                              p["w_down"], x.reshape(-1, d), k, cf, 0, e)
        return out.reshape(b, s, d), aux

    mesh = pol.mesh
    mode = pol.moe_mode()
    bspec = pol.batch_spec
    if mode == "replicate":
        out, aux = _moe_local(p["router"], p["w_gate"], p["w_up"],
                              p["w_down"], x.reshape(-1, d), k, cf, 0, e)
        return out.reshape(b, s, d), aux

    if mode == "model":
        n_model = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
        e_loc = e // n_model

        def body(xl, router, wg, wu, wd):
            bl, sl, _ = xl.shape
            e_start = jax.lax.axis_index("model") * e_loc
            out, aux = _moe_local(router, wg, wu, wd, xl.reshape(-1, d),
                                  k, cf, e_start, e)
            out = jax.lax.psum(out, "model")
            aux = jax.lax.pmean(aux, "model")
            return out.reshape(bl, sl, d), aux

        # router replicated; expert weights sharded over model (EP)
        out, aux = jax.shard_map(
            body, mesh=mesh,
            in_specs=(P(bspec, None, None), P(None, None),
                      P("model", None, None), P("model", None, None),
                      P("model", None, None)),
            out_specs=(P(bspec, None, None), P()),
            check_vma=False,
        )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
        return out, aux

    # ---- grid EP: experts over `data`, d_ff over `model` ----
    # Each (data, model) cell holds [E/n_data, d, f/n_model] — the layout
    # that makes the 1T-param MoEs fit per chip (DESIGN.md). Dispatch:
    #   - decode / tiny T: all-gather tokens over `data` (cheap, lowest
    #     latency), compute local experts, reduce-scatter back.
    #   - train / prefill: ALL-TO-ALL dispatch — each cell sends each
    #     assignment only to the data-shard owning its expert; bytes are
    #     k*cf/n_data of the all-gather (§Perf iteration 7).
    n_data = dict(zip(mesh.axis_names, mesh.devices.shape))["data"]
    e_loc = e // n_data
    data_in_batch = bspec is not None and "data" in (
        bspec if isinstance(bspec, tuple) else (bspec,))
    # dispatch-strategy cost model (§Perf iterations 7-8): a2a moves
    # ~2*k*cf/n_data of the tokens (out + back); AG+reduce-scatter moves
    # ~(1 + 1/n_data). Choose per-config: a2a wins for low-k MoEs (jamba
    # top-2: 0.31x), AG wins for high-k (kimi top-8: 1.25x).
    a2a_bytes = 2 * k * cf / n_data
    use_a2a = (data_in_batch and (b // _dsize(pol) * s) >= A2A_MIN_TOKENS
               and a2a_bytes < 1.0 + 1.0 / n_data)

    if use_a2a:
        def body_a2a(xl, router, wg, wu, wd):
            out, aux = _grid_a2a(pol, xl, router, wg, wu, wd, k, cf,
                                 e, e_loc, n_data, d)
            return out, aux

        out, aux = jax.shard_map(
            body_a2a, mesh=mesh,
            in_specs=(P(bspec, None, None), P(None, None),
                      P("data", None, "model"), P("data", None, "model"),
                      P("data", "model", None)),
            out_specs=(P(bspec, None, None), P()),
            check_vma=False,
        )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
        return out, aux

    def body_grid(xl, router, wg, wu, wd):
        d_idx = jax.lax.axis_index("data")
        if data_in_batch:
            x_all = jax.lax.all_gather(xl, "data", tiled=True)
        else:
            x_all = xl
        bl, sl, _ = x_all.shape
        e_start = d_idx * e_loc
        out, aux = _moe_local(router, wg, wu, wd, x_all.reshape(-1, d),
                              k, cf, e_start, e)
        out = out.reshape(bl, sl, d)
        if data_in_batch:
            # reduce-scatter: combine expert partials over `data` while
            # returning each cell only its own rows (vs psum + slice:
            # n_data x fewer collective bytes — §Perf iteration 2)
            out = jax.lax.psum_scatter(out, "data", scatter_dimension=0,
                                       tiled=True)
        else:
            out = jax.lax.psum(out, "data")
        out = jax.lax.psum(out, "model")
        aux = jax.lax.pmean(aux, ("data", "model"))
        return out, aux

    out, aux = jax.shard_map(
        body_grid, mesh=mesh,
        in_specs=(P(bspec, None, None), P(None, None),
                  P("data", None, "model"), P("data", None, "model"),
                  P("data", "model", None)),
        out_specs=(P(bspec, None, None), P()),
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return out, aux
