"""Mamba-1 selective-SSM block (falcon-mamba / jamba mamba layers).

Train/prefill uses a chunked scan: sequential lax.scan over sequence chunks
with an associative scan inside each chunk — bounded memory, log-depth
within chunks, and the exact structure of kernels/mamba_scan.py.

Decode carries (conv_state [B, conv, d_inner], ssm_state [B, d_inner, N]);
the state is shard-resident over the `model` axis (d_inner sharded) and
never crosses the interconnect — the SSM analogue of in-storage KV.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import _init


def mamba_init(key, d, d_inner, n_state, dt_rank, conv, dtype):
    ks = jax.random.split(key, 7)
    dt_init = jnp.exp(jax.random.uniform(ks[5], (d_inner,), jnp.float32)
                      * (np.log(0.1) - np.log(0.001)) + np.log(0.001))
    inv_softplus = dt_init + jnp.log(-jnp.expm1(-dt_init))
    return {
        "in_proj": _init(ks[0], (d, 2 * d_inner), dtype),
        "conv_w": _init(ks[1], (conv, d_inner), dtype, scale=1.0 / np.sqrt(conv)),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "x_proj": _init(ks[2], (d_inner, dt_rank + 2 * n_state), dtype),
        "dt_proj": _init(ks[3], (dt_rank, d_inner), dtype,
                         scale=dt_rank ** -0.5),
        "dt_bias": inv_softplus.astype(jnp.float32),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n_state + 1, dtype=jnp.float32), (d_inner, n_state))),
        "D": jnp.ones((d_inner,), jnp.float32),
        "out_proj": _init(ks[4], (d_inner, d), dtype),
    }


def _ssm_inputs(p, xc, n_state, dt_rank):
    """xc: [B, T, d_inner] (post-conv). Returns dt, B_t, C_t, A."""
    dbc = jnp.einsum("btd,dr->btr", xc, p["x_proj"].astype(xc.dtype))
    dt_low, b_t, c_t = jnp.split(dbc, [dt_rank, dt_rank + n_state], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("btr,rd->btd", dt_low, p["dt_proj"].astype(xc.dtype))
        .astype(jnp.float32) + p["dt_bias"])                 # [B,T,d_inner]
    a = -jnp.exp(p["A_log"])                                 # [d_inner, N]
    return dt, b_t.astype(jnp.float32), c_t.astype(jnp.float32), a


def _chunk_scan(a_bar, bx, h0):
    """Associative scan within a chunk. a_bar, bx: [B, T, d, N]; h0: [B, d, N].
    Returns hs [B, T, d, N] and final h."""
    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2
    # fold h0 into the first element
    bx = bx.at[:, 0].add(a_bar[:, 0] * h0)
    a_s, hs = jax.lax.associative_scan(combine, (a_bar, bx), axis=1)
    return hs, hs[:, -1]


def causal_conv(p, x, conv):
    """Depthwise causal conv1d. x: [B, T, d_inner]."""
    w = p["conv_w"].astype(x.dtype)                          # [conv, d]
    xp = jnp.pad(x, ((0, 0), (conv - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(conv))
    return out + p["conv_b"].astype(x.dtype)


def mamba_forward(cfg, p, x, chunk: int = 256):
    """Full-sequence forward. x: [B, T, d] -> [B, T, d]."""
    b, t, d = x.shape
    n, dr, conv = cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv
    xz = jnp.einsum("btd,de->bte", x, p["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)                        # [B,T,d_inner]
    xc = jax.nn.silu(causal_conv(p, xi, conv))
    dt, b_t, c_t, a = _ssm_inputs(p, xc, n, dr)
    xf = xc.astype(jnp.float32)

    chunk = min(chunk, t)
    pad = (-t) % chunk
    def padt(v):
        return jnp.pad(v, ((0, 0), (0, pad)) + ((0, 0),) * (v.ndim - 2))
    dtp, btp, ctp, xfp = padt(dt), padt(b_t), padt(c_t), padt(xf)
    nchunk = (t + pad) // chunk

    def step(h, args):
        dt_c, b_c, c_c, x_c = args                           # [B,chunk,...]
        a_bar = jnp.exp(dt_c[..., None] * a)                 # [B,c,d,N]
        bx = (dt_c * x_c)[..., None] * b_c[:, :, None, :]    # [B,c,d,N]
        hs, h_new = _chunk_scan(a_bar, bx, h)
        y = jnp.einsum("bcdn,bcn->bcd", hs, c_c)
        return h_new, y

    h0 = jnp.zeros((b, cfg.d_inner, n), jnp.float32)
    _, ys = jax.lax.scan(
        step, h0,
        tuple(v.reshape(b, nchunk, chunk, *v.shape[2:]).swapaxes(0, 1)
              for v in (dtp, btp, ctp, xfp)))
    y = ys.swapaxes(0, 1).reshape(b, nchunk * chunk, cfg.d_inner)[:, :t]
    y = y + xf * p["D"]
    y = (y * jax.nn.silu(z.astype(jnp.float32)))
    return jnp.einsum("bte,ed->btd", y.astype(x.dtype), p["out_proj"])


def mamba_prefill(cfg, p, x, length=None, chunk: int = 256):
    """Forward + final decode states (conv window + SSM state at `length`)."""
    b, t, d = x.shape
    out = mamba_forward(cfg, p, x, chunk)
    # recompute states at position `length` (cheap relative to forward)
    n, dr, conv = cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv
    xz = jnp.einsum("btd,de->bte", x, p["in_proj"])
    xi, _ = jnp.split(xz, 2, axis=-1)
    length = t if length is None else length
    idx = jnp.maximum(jnp.arange(conv) + length - conv, 0)
    conv_state = jnp.take(xi, idx, axis=1)                   # [B, conv, d_in]
    xc = jax.nn.silu(causal_conv(p, xi, conv))
    dt, b_t, c_t, a = _ssm_inputs(p, xc, n, dr)
    mask = (jnp.arange(t) < length)[None, :, None]
    dt = jnp.where(mask, dt, 0.0)                            # a_bar=1, bx=0
    a_bar = jnp.exp(dt[..., None] * a)
    bx = (dt * xc.astype(jnp.float32))[..., None] * b_t[:, :, None, :]

    def step(h, args):
        ab, bx_t = args
        return ab * h + bx_t, None
    h, _ = jax.lax.scan(step, jnp.zeros((b, cfg.d_inner, n), jnp.float32),
                        (a_bar.swapaxes(0, 1), bx.swapaxes(0, 1)))
    return out, {"conv": conv_state, "ssm": h}


def mamba_decode(cfg, p, x, state):
    """One decode step. x: [B, 1, d]; state: {conv [B,conv,d_in], ssm [B,d_in,N]}."""
    b = x.shape[0]
    n, dr, conv = cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv
    xz = jnp.einsum("bd,de->be", x[:, 0], p["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)                        # [B, d_inner]
    conv_state = jnp.concatenate([state["conv"][:, 1:], xi[:, None]], axis=1)
    w = p["conv_w"].astype(xi.dtype)
    xc = jax.nn.silu(jnp.sum(conv_state * w[None], axis=1)
                     + p["conv_b"].astype(xi.dtype))
    dt, b_t, c_t, a = _ssm_inputs(p, xc[:, None], n, dr)
    dt, b_t, c_t = dt[:, 0], b_t[:, 0], c_t[:, 0]
    a_bar = jnp.exp(dt[..., None] * a)                       # [B,d,N]
    h = a_bar * state["ssm"] + (dt * xc.astype(jnp.float32))[..., None] \
        * b_t[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, c_t) + xc.astype(jnp.float32) * p["D"]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("be,ed->bd", y.astype(x.dtype), p["out_proj"])
    return out[:, None], {"conv": conv_state, "ssm": h}
