"""Shared model building blocks: norms, RoPE, GQA attention (chunked
flash-style for long prefill), swiglu MLP, embeddings.

Parameters are plain dict pytrees. Layer-stacked variants (leading [L] dim)
are produced by vmapping the per-layer init over split keys.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# ----------------------------------------------------------------------------
# init
# ----------------------------------------------------------------------------

def _init(key, shape, dtype, scale=None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def stack_init(per_layer_init, key, n_layers):
    """vmap a per-layer init over split keys -> stacked [L, ...] pytree."""
    keys = jax.random.split(key, n_layers)
    return jax.vmap(per_layer_init)(keys)


# ----------------------------------------------------------------------------
# norms
# ----------------------------------------------------------------------------

def norm_init(key, d, kind, dtype):
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype)}


def apply_norm(p, x, kind, eps=1e-6):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        return (y * p["scale"].astype(jnp.float32)
                + p["bias"].astype(jnp.float32)).astype(x.dtype)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ----------------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, hd]; positions: [..., S] int32 (broadcastable)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))           # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoid_at(position, d: int):
    """Sinusoidal embedding for a single (traced) position -> [d]."""
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)
    ang = position.astype(jnp.float32) / jnp.power(10000.0, dim / d)
    out = jnp.zeros((d,), jnp.float32)
    return out.at[0::2].set(jnp.sin(ang)).at[1::2].set(jnp.cos(ang))


def sinusoid_positions(max_len: int, d: int):
    """Whisper-style sinusoidal absolute positions (extendable)."""
    pos = np.arange(max_len)[:, None]
    dim = np.arange(0, d, 2)[None, :]
    ang = pos / np.power(10000.0, dim / d)
    out = np.zeros((max_len, d), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return jnp.asarray(out)


# ----------------------------------------------------------------------------
# attention (full-sequence, chunked flash-style in pure jnp)
# ----------------------------------------------------------------------------

def attn_init(key, d, n_heads, n_kv, head_dim, dtype):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": _init(kq, (d, n_heads, head_dim), dtype),
        "wk": _init(kk, (d, n_kv, head_dim), dtype),
        "wv": _init(kv, (d, n_kv, head_dim), dtype),
        "wo": _init(ko, (n_heads, head_dim, d), dtype,
                    scale=1.0 / np.sqrt(n_heads * head_dim)),
    }


def qkv_proj(p, x):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    return q, k, v


def o_proj(p, attn_out):
    return jnp.einsum("bshk,hkd->bsd", attn_out, p["wo"])


def _expand_kv(k, n_heads):
    """[B,S,KV,hd] -> [B,S,H,hd] by repeating groups (GQA)."""
    b, s, kv, hd = k.shape
    g = n_heads // kv
    return jnp.repeat(k, g, axis=2) if g > 1 else k


def chunked_attention(q, k, v, *, causal: bool, q_chunk: int = 512,
                      k_chunk: int = 1024, q_offset: int = 0):
    """Flash-style online-softmax attention in pure jnp.

    q: [B, Sq, H, hd]; k, v: [B, Sk, H, hd] (already GQA-expanded).
    Memory is bounded by q_chunk*k_chunk score tiles. Doubles as the oracle
    for kernels/flash_attention.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    q_chunk = min(q_chunk, sq)
    k_chunk = min(k_chunk, sk)
    # pad to chunk multiples
    pq = (-sq) % q_chunk
    pk = (-sk) % k_chunk
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = qp.shape[1] // q_chunk, kp.shape[1] // k_chunk
    qp = qp.reshape(b, nq, q_chunk, h, hd)
    kp = kp.reshape(b, nk, k_chunk, h, hd)
    vp = vp.reshape(b, nk, k_chunk, h, hd)
    scale = 1.0 / np.sqrt(hd)

    def q_step(_, qi):
        qblk, qidx = qi                                   # [B,qc,H,hd], scalar
        qpos = q_offset + qidx * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, ki):
            m, l, acc = carry
            kblk, vblk, kidx = ki
            kpos = kidx * k_chunk + jnp.arange(k_chunk)
            s = jnp.einsum("bqhk,bchk->bqhc", qblk.astype(jnp.float32),
                           kblk.astype(jnp.float32)) * scale
            mask = (kpos < sk)[None, None, None, :]       # [1,1,1,c]
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])[None, :, None, :]
            s = jnp.where(mask, s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(mask, p, 0.0)
            corr = jnp.exp(jnp.where(jnp.isinf(m), 0.0, m) - m_safe)
            corr = jnp.where(jnp.isinf(m), 0.0, corr)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqhc,bchk->bqhk", p, vblk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        init = (jnp.full((b, q_chunk, h), -jnp.inf, jnp.float32),
                jnp.zeros((b, q_chunk, h), jnp.float32),
                jnp.zeros((b, q_chunk, h, hd), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            kv_step, init,
            (kp.transpose(1, 0, 2, 3, 4), vp.transpose(1, 0, 2, 3, 4),
             jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-20)[..., None]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(
        q_step, None, (qp.transpose(1, 0, 2, 3, 4), jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, nq * q_chunk, h, hd)
    return out[:, :sq]


def full_attention(q, k, v, n_heads, *, causal=True, q_offset=0,
                   q_chunk=512, k_chunk=1024):
    k = _expand_kv(k, n_heads)
    v = _expand_kv(v, n_heads)
    return chunked_attention(q, k, v, causal=causal, q_offset=q_offset,
                             q_chunk=q_chunk, k_chunk=k_chunk)


# ----------------------------------------------------------------------------
# MLP (swiglu)
# ----------------------------------------------------------------------------

def mlp_init(key, d, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": _init(k1, (d, d_ff), dtype),
        "w_up": _init(k2, (d, d_ff), dtype),
        "w_down": _init(k3, (d_ff, d), dtype),
    }


def apply_mlp(p, x, pol=None):
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    if pol is not None:
        h = pol.c(h, _ff_spec(pol))
    return h @ p["w_down"]


def _ff_spec(pol):
    try:
        from jax.sharding import PartitionSpec as P
        if pol.w_ff_in() is None:
            return None
        shard = pol.w_ff_in()[1]
        return P(pol.batch_spec, None, shard)
    except Exception:
        return None


# ----------------------------------------------------------------------------
# embeddings
# ----------------------------------------------------------------------------

def embed_init(key, vocab, d, dtype, tie=False):
    k1, k2 = jax.random.split(key)
    p = {"tok": _init(k1, (vocab, d), dtype, scale=0.02)}
    if not tie:
        p["unembed"] = _init(k2, (d, vocab), dtype)
    return p


def embed_tokens(p, tokens):
    return jnp.take(p["tok"], tokens, axis=0)


def unembed(p, x):
    if "unembed" in p:
        return x @ p["unembed"]
    return x @ p["tok"].T
