"""Unified decoder model covering all assigned families:

  dense / moe / vlm : homogeneous attention blocks (+ MLP or MoE FFN)
  ssm               : mamba-only blocks (no FFN — mamba1)
  hybrid (jamba)    : period-structured mix (1 attn per `attn_period`,
                      MoE every `moe_every`)
  encdec (whisper)  : encoder stack + decoder stack with cross-attention

Layers are scanned over "periods" (period = lcm of the structural
periodicities, 1 for homogeneous models) so the HLO stays one-period-sized
regardless of depth — essential for 512-device compile times, and the
layer-wise KV "transmission" pipeline falls out of the scan schedule.

Modes:
  train  : full-seq causal, next-token loss (+ MoE aux)
  prefill: full-seq causal, returns logits + populated paged-KV cache
  decode : one token against the cache through core.offload (the paper's
           in-storage attention path)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.offload import decode_attention
from repro.core.paged_kv import (KVLayout, append_token, init_layer_cache,
                                 make_layout, write_prefill)
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models.layers import (apply_mlp, apply_norm, apply_rope,
                                 attn_init, embed_init, embed_tokens,
                                 full_attention, mlp_init, norm_init, o_proj,
                                 qkv_proj, sinusoid_at, sinusoid_positions,
                                 stack_init, unembed, _init)
from repro.sharding.policy import NullPolicy

# ----------------------------------------------------------------------------
# structure
# ----------------------------------------------------------------------------

def layer_period(cfg) -> int:
    p = 1
    if cfg.family == "hybrid" and cfg.attn_period:
        p = cfg.attn_period
    if cfg.n_experts and cfg.moe_every > 1:
        p = int(np.lcm(p, cfg.moe_every))
    return p


def layer_kinds(cfg) -> Tuple[Tuple[str, str], ...]:
    """(mixer, ffn) kind for each position within one period."""
    period = layer_period(cfg)
    kinds = []
    for j in range(period):
        if cfg.family == "ssm":
            mixer = "mamba"
        elif cfg.family == "hybrid":
            mixer = "attn" if j % cfg.attn_period == cfg.attn_offset else "mamba"
        else:
            mixer = "attn"
        if cfg.family == "ssm":
            ffn = "none"                       # mamba1 block has no FFN
        elif cfg.n_experts and j % cfg.moe_every == cfg.moe_every - 1:
            ffn = "moe"
        else:
            ffn = "mlp"
        kinds.append((mixer, ffn))
    return tuple(kinds)


def n_periods(cfg) -> int:
    period = layer_period(cfg)
    assert cfg.n_layers % period == 0, (cfg.name, cfg.n_layers, period)
    return cfg.n_layers // period


# ----------------------------------------------------------------------------
# init
# ----------------------------------------------------------------------------

def _block_init(cfg, kind, key, dtype, cross: bool = False):
    mixer, ffn = kind
    km, kf, kn1, kn2, kc, kn3 = jax.random.split(key, 6)
    p: Dict[str, Any] = {"norm1": norm_init(kn1, cfg.d_model, cfg.norm, dtype)}
    if mixer == "attn":
        p["attn"] = attn_init(km, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                              cfg.head_dim, dtype)
    else:
        p["mamba"] = mamba_mod.mamba_init(
            km, cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank,
            cfg.ssm_conv, dtype)
    if cross:
        p["norm_cross"] = norm_init(kn3, cfg.d_model, cfg.norm, dtype)
        p["cross"] = attn_init(kc, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.head_dim, dtype)
    if ffn == "mlp":
        p["norm2"] = norm_init(kn2, cfg.d_model, cfg.norm, dtype)
        p["mlp"] = mlp_init(kf, cfg.d_model, cfg.d_ff, dtype)
    elif ffn == "moe":
        p["norm2"] = norm_init(kn2, cfg.d_model, cfg.norm, dtype)
        p["moe"] = moe_mod.moe_init(kf, cfg.d_model, cfg.d_ff,
                                    cfg.n_experts, dtype)
    return p


def init_params(cfg, key) -> Dict[str, Any]:
    dtype = cfg.activation_dtype
    kinds = layer_kinds(cfg)
    np_ = n_periods(cfg)
    ke, kb, kn, kenc, kfr = jax.random.split(key, 5)
    params: Dict[str, Any] = {
        "embed": embed_init(ke, cfg.padded_vocab, cfg.d_model, dtype,
                            tie=cfg.tie_embeddings),
        "final_norm": norm_init(kn, cfg.d_model, cfg.norm, dtype),
    }
    cross = cfg.family == "encdec"
    blocks = []
    for j, kind in enumerate(kinds):
        kj = jax.random.fold_in(kb, j)
        blocks.append(stack_init(
            lambda k, kind=kind: _block_init(cfg, kind, k, dtype, cross=cross),
            kj, np_))
    params["blocks"] = tuple(blocks)
    if cfg.family == "encdec":
        params["encoder"] = {
            "blocks": stack_init(
                lambda k: _block_init(cfg, ("attn", "mlp"), k, dtype),
                kenc, cfg.n_encoder_layers),
            "final_norm": norm_init(jax.random.fold_in(kenc, 1), cfg.d_model,
                                    cfg.norm, dtype),
        }
    if cfg.frontend != "none":
        params["frontend"] = {
            "proj": _init(kfr, (cfg.d_model, cfg.d_model), dtype)}
    return params


# ----------------------------------------------------------------------------
# cache
# ----------------------------------------------------------------------------

def make_layouts(cfg, max_seq: int, n_workers: int):
    return make_layout(cfg, max_seq, n_workers)


def init_cache(cfg, batch: int, max_seq: int, n_workers: int,
               enc_len: int = 0):
    """Decode cache pytree: tuple over period positions; each entry stacked
    over periods. Attention -> paged KV store; mamba -> (conv, ssm) state."""
    dtype = cfg.activation_dtype
    layout = make_layout(cfg, max_seq, n_workers)
    np_ = n_periods(cfg)
    entries = []
    for mixer, _ in layer_kinds(cfg):
        if mixer == "attn":
            one = init_layer_cache(layout, batch, cfg.kv_store_dtype)
            entry = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (np_,) + a.shape), one)
            if cfg.family == "encdec":
                entry["cross_k"] = jnp.zeros(
                    (np_, batch, enc_len, cfg.n_kv_heads, cfg.head_dim), dtype)
                entry["cross_v"] = jnp.zeros_like(entry["cross_k"])
        else:
            entry = {
                "conv": jnp.zeros((np_, batch, cfg.ssm_conv, cfg.d_inner),
                                  dtype),
                "ssm": jnp.zeros((np_, batch, cfg.d_inner, cfg.ssm_state),
                                 jnp.float32),
            }
        entries.append(entry)
    return {"layers": tuple(entries), "length": jnp.zeros((), jnp.int32)}


# ----------------------------------------------------------------------------
# sublayers
# ----------------------------------------------------------------------------

def _attn_full(cfg, pol, p, x, positions, causal=True, kv=None):
    """Full-sequence attention. Returns (out, (k, v)) for cache writing."""
    q, k, v = qkv_proj(p, x)
    if cfg.rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = pol.c(q, pol.acts(heads=True))
    if kv is not None:                         # cross-attention
        k, v = kv
    out = full_attention(q, k, v, cfg.n_heads, causal=causal)
    out = pol.c(out, pol.acts(heads=True))
    return o_proj(p, out), (k, v)


def _attn_decode(cfg, pol, layout, p, x, cache, length):
    """Single-token attention through the in-storage engine."""
    q, k, v = qkv_proj(p, x)                   # [B,1,H,hd], [B,1,KV,hd]
    if cfg.rope:
        pos = jnp.full((x.shape[0], 1), length, jnp.int32)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    cache = append_token(layout, cache, k[:, 0], v[:, 0], length)
    out = decode_attention(cfg, pol, layout, q[:, 0], cache, length + 1)
    return o_proj(p, out[:, None]), cache


def _ffn(cfg, pol, p, x, kind):
    if kind == "none":
        return x, 0.0
    h = apply_norm(p["norm2"], x, cfg.norm)
    if kind == "moe":
        out, aux = moe_mod.apply_moe(cfg, pol, p["moe"], h)
        return x + out, aux
    return x + apply_mlp(p["mlp"], h, pol), 0.0


def _block_full(cfg, pol, kind, p, x, positions, mode, enc_out=None,
                layout=None, length=None):
    """One block, full-sequence (train/prefill). Returns (x, aux, cache)."""
    mixer, ffn = kind
    h = apply_norm(p["norm1"], x, cfg.norm)
    cache_entry = None
    if mixer == "attn":
        out, (k, v) = _attn_full(cfg, pol, p["attn"], h, positions)
        x = x + out
        if mode == "prefill":
            one = init_layer_cache(layout, x.shape[0],
                                    cfg.kv_store_dtype)
            cache_entry = write_prefill(layout, one, k, v, lengths=length)
        if enc_out is not None:                # whisper cross-attention
            hc = apply_norm(p["norm_cross"], x, cfg.norm)
            qc, kc, vc = qkv_proj(p["cross"], hc)
            kc = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wk"])
            vc = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wv"])
            outc = full_attention(qc, kc, vc, cfg.n_heads, causal=False)
            x = x + o_proj(p["cross"], outc)
            if mode == "prefill":
                cache_entry["cross_k"] = kc
                cache_entry["cross_v"] = vc
    else:
        if mode == "prefill":
            out, st = mamba_mod.mamba_prefill(cfg, p["mamba"], h,
                                              length=length)
            cache_entry = st
        else:
            out = mamba_mod.mamba_forward(cfg, p["mamba"], h)
        x = x + out
    x, aux = _ffn(cfg, pol, p, x, ffn)
    return x, aux, cache_entry


def _block_decode(cfg, pol, kind, p, x, cache, length, layout):
    mixer, ffn = kind
    h = apply_norm(p["norm1"], x, cfg.norm)
    if mixer == "attn":
        out, new_cache = _attn_decode(cfg, pol, layout, p["attn"], h,
                                      {k: v for k, v in cache.items()
                                       if not k.startswith("cross_")},
                                      length)
        if "cross_k" in cache:
            new_cache = dict(new_cache)
            new_cache["cross_k"] = cache["cross_k"]
            new_cache["cross_v"] = cache["cross_v"]
        x = x + out
        if "cross_k" in cache:
            hc = apply_norm(p["norm_cross"], x, cfg.norm)
            qc = jnp.einsum("bsd,dhk->bshk", hc, p["cross"]["wq"])
            outc = full_attention(qc, cache["cross_k"], cache["cross_v"],
                                  cfg.n_heads, causal=False)
            x = x + o_proj(p["cross"], outc)
    else:
        out, new_cache = mamba_mod.mamba_decode(cfg, p["mamba"], h, cache)
        x = x + out
    x, aux = _ffn(cfg, pol, p, x, ffn)
    return x, new_cache


# ----------------------------------------------------------------------------
# stacks
# ----------------------------------------------------------------------------

def _run_encoder(cfg, pol, params, frames):
    """Whisper encoder: frames [B, F, d] (frontend stub output)."""
    enc = params["encoder"]
    x = frames @ params["frontend"]["proj"]
    pos = sinusoid_positions(frames.shape[1], cfg.d_model).astype(x.dtype)
    x = x + pos[None]
    positions = jnp.broadcast_to(jnp.arange(frames.shape[1]),
                                 frames.shape[:2])

    def body(x, p):
        h = apply_norm(p["norm1"], x, cfg.norm)
        out, _ = _attn_full(cfg, pol, p["attn"], h, positions, causal=False)
        x = x + out
        x, _ = _ffn(cfg, pol, p, x, "mlp")
        return x, None

    if cfg.scan_layers:
        x, _ = jax.lax.scan(lambda c, p: body(c, p), x, enc["blocks"])
    else:
        for i in range(cfg.n_encoder_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[i], enc["blocks"]))
    return apply_norm(enc["final_norm"], x, cfg.norm)


def _embed_input(cfg, pol, params, batch, mode, length=None):
    tokens = batch["token"] if mode == "decode" else batch["tokens"]
    x = embed_tokens(params["embed"], tokens)
    if not cfg.rope:
        s = tokens.shape[1]
        if mode == "decode":
            pos_emb = sinusoid_at(jnp.asarray(length), cfg.d_model)
            x = x + pos_emb.astype(x.dtype)[None, None, :]
        else:
            x = x + sinusoid_positions(s, cfg.d_model).astype(x.dtype)[None]
    if cfg.frontend == "vision" and mode != "decode" and "patches" in batch:
        patches = batch["patches"] @ params["frontend"]["proj"]
        n = patches.shape[1]
        x = jnp.concatenate([patches.astype(x.dtype), x[:, n:]], axis=1)
    return pol.c(x, pol.acts())


def forward(cfg, pol, params, batch, mode: str, cache=None,
            layout: Optional[KVLayout] = None, length=None):
    """Returns (logits, aux, cache)."""
    kinds = layer_kinds(cfg)
    np_ = n_periods(cfg)
    if layout is None:
        n_workers = 1 if isinstance(pol, NullPolicy) else \
            dict(zip(pol.mesh.axis_names, pol.mesh.devices.shape)).get("model", 1)
        seq = cfg.max_seq if mode == "decode" else batch["tokens"].shape[1]
        layout = make_layout(cfg, seq, n_workers)

    enc_out = None
    if cfg.family == "encdec" and mode != "decode":
        enc_out = _run_encoder(cfg, pol, params, batch["frames"])

    if mode == "decode":
        length = cache["length"]
        x = _embed_input(cfg, pol, params, batch, mode, length=length)

        def body(x, xs):
            block_p, cache_p = xs
            outs = []
            for j, kind in enumerate(kinds):
                pj = jax.tree.map(lambda a: a, block_p[j])
                x, new_c = _block_decode(cfg, pol, kind, pj, x, cache_p[j],
                                         length, layout)
                outs.append(new_c)
            return x, tuple(outs)

        if cfg.scan_layers:
            x, new_layers = jax.lax.scan(
                body, x, (params["blocks"], cache["layers"]))
        else:
            new_entries = [[] for _ in kinds]
            for i in range(np_):
                blk = jax.tree.map(lambda a: a[i], params["blocks"])
                cch = jax.tree.map(lambda a: a[i], cache["layers"])
                x, outs = body(x, (blk, cch))
                for j, o in enumerate(outs):
                    new_entries[j].append(o)
            new_layers = tuple(
                jax.tree.map(lambda *xs: jnp.stack(xs), *e)
                for e in new_entries)
        new_cache = {"layers": new_layers, "length": length + 1}
        x = apply_norm(params["final_norm"], x, cfg.norm)
        logits = unembed(params["embed"], x)
        logits = pol.c(logits, pol.logits())
        return logits, 0.0, new_cache

    # ---- train / prefill ----
    x = _embed_input(cfg, pol, params, batch, mode)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

    def body(carry, block_p):
        x, aux = carry
        caches = []
        for j, kind in enumerate(kinds):
            x, a, c = _block_full(cfg, pol, kind, block_p[j], x, positions,
                                  mode, enc_out=enc_out, layout=layout,
                                  length=length)
            aux = aux + a
            caches.append(c)
        x = pol.c(x, pol.acts())
        return (x, aux), tuple(caches) if mode == "prefill" else None

    body_fn = body
    if cfg.remat and mode == "train":
        policy = (jax.checkpoint_policies.nothing_saveable
                  if cfg.remat_policy == "full" else
                  jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        body_fn = jax.checkpoint(body, policy=policy)

    if cfg.scan_layers:
        (x, aux), caches = jax.lax.scan(body_fn, (x, 0.0), params["blocks"])
    else:
        aux = 0.0
        cache_entries = [[] for _ in kinds]
        for i in range(np_):
            blk = jax.tree.map(lambda a: a[i], params["blocks"])
            (x, aux), cs = body_fn((x, aux), blk)
            if mode == "prefill":
                for j, c in enumerate(cs):
                    cache_entries[j].append(c)
        caches = tuple(
            jax.tree.map(lambda *xs: jnp.stack(xs), *e)
            for e in cache_entries) if mode == "prefill" else None

    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = unembed(params["embed"], x)
    logits = pol.c(logits, pol.logits())
    new_cache = None
    if mode == "prefill":
        new_cache = {"layers": caches,
                     "length": jnp.asarray(
                         length if length is not None else x.shape[1],
                         jnp.int32)}
    return logits, aux, new_cache
