"""Offline batched serving sessions (the paper's scenario: offline,
long-context, large-batch, uniform lengths — input/output 1024/1024 in the
paper's evaluation). A Session owns params + paged cache and exposes
prefill/generate; the BatchScheduler packs uniform-length requests into
full batches for throughput.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.paged_kv import make_layout
from repro.models.transformer import forward
from repro.serving.decode import jit_serve_step, make_prefill_step
from repro.sharding.policy import NULL


@dataclass
class Session:
    cfg: object
    params: object
    pol: object = NULL
    max_seq: int = 0
    cache: object = None
    layout: object = None
    _serve = None

    def __post_init__(self):
        self.max_seq = self.max_seq or self.cfg.max_seq
        n_workers = 1 if self.pol is NULL else dict(
            zip(self.pol.mesh.axis_names,
                self.pol.mesh.devices.shape)).get("model", 1)
        self.layout = make_layout(self.cfg, self.max_seq, n_workers)
        self._serve = jit_serve_step(self.cfg, self.pol, self.layout,
                                     donate_cache=True)

    def prefill(self, batch: dict) -> jax.Array:
        length = batch["tokens"].shape[1]
        step = make_prefill_step(self.cfg, self.pol, self.layout,
                                 length=length)
        if self.pol is NULL:
            logits, self.cache = jax.jit(step)(self.params, batch)
        else:
            from repro.serving.decode import cache_shardings
            cshard = cache_shardings(self.cfg, self.pol, self.layout)
            logits, self.cache = jax.jit(
                step, out_shardings=(None, cshard))(self.params, batch)
        return logits

    def decode_step(self, token) -> jax.Array:
        logits, self.cache = self._serve(self.params, self.cache, token)
        return logits

    def generate(self, batch: dict, n_tokens: int, greedy: bool = True,
                 key=None) -> np.ndarray:
        logits = self.prefill(batch)
        b = batch["tokens"].shape[0]
        out = []
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        for i in range(n_tokens):
            out.append(np.asarray(tok)[:, 0])
            logits = self.decode_step(tok)
            if greedy or key is None:
                tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            else:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(
                    sub, logits[:, -1]).astype(jnp.int32)[:, None]
        return np.stack(out, axis=1)


@dataclass
class BatchScheduler:
    """Packs uniform-length offline requests into full batches (throughput-
    oriented continuous batching at page granularity)."""
    batch_size: int
    queue: List[np.ndarray] = field(default_factory=list)

    def submit(self, tokens: np.ndarray):
        self.queue.append(tokens)

    def next_batch(self) -> Optional[np.ndarray]:
        if len(self.queue) < self.batch_size:
            return None
        take, self.queue = (self.queue[:self.batch_size],
                            self.queue[self.batch_size:])
        return np.stack(take)
