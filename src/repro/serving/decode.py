"""Serving steps: prefill_step (compute-side, writes the paged store
layer-wise) and serve_step (one token; attention through the in-storage
engine). Factories return jit'd callables with explicit shardings, donating
the cache buffer so decode is allocation-free at steady state.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.paged_kv import cache_specs, make_layout
from repro.models.transformer import forward, init_cache, layer_kinds, n_periods
from repro.sharding.policy import NullPolicy


def cache_shardings(cfg, pol, layout):
    """NamedSharding pytree matching init_cache output."""
    if isinstance(pol, NullPolicy):
        return None
    from jax.sharding import PartitionSpec as P
    specs = cache_specs(layout, pol)
    b = pol.batch_spec

    def prepend(spec):     # add the stacked period dim
        return P(*((None,) + tuple(spec)))

    entries = []
    for mixer, _ in layer_kinds(cfg):
        if mixer == "attn":
            e = {k: pol.named(prepend(v)) for k, v in specs.items()}
            if cfg.family == "encdec":
                e["cross_k"] = pol.named(P(None, b, None, None, None))
                e["cross_v"] = pol.named(P(None, b, None, None, None))
        else:
            e = {"conv": pol.named(P(None, b, None, "model")),
                 "ssm": pol.named(P(None, b, "model", None))}
        entries.append(e)
    return {"layers": tuple(entries), "length": pol.named(P())}


def make_prefill_step(cfg, pol, layout, length=None):
    def prefill_step(params, batch):
        logits, _, cache = forward(cfg, pol, params, batch, "prefill",
                                   layout=layout, length=length)
        return logits[:, -1:], cache
    return prefill_step


def make_serve_step(cfg, pol, layout):
    def serve_step(params, cache, token):
        logits, _, cache = forward(cfg, pol, params, {"token": token},
                                   "decode", cache=cache, layout=layout)
        return logits, cache
    return serve_step


def jit_serve_step(cfg, pol, layout, donate_cache: bool = True):
    fn = make_serve_step(cfg, pol, layout)
    if isinstance(pol, NullPolicy):
        return jax.jit(fn, donate_argnums=(1,) if donate_cache else ())
    cshard = cache_shardings(cfg, pol, layout)
    tok = pol.named(jax.sharding.PartitionSpec(pol.batch_spec, None))
    return jax.jit(fn,
                   in_shardings=(None, cshard, tok),
                   out_shardings=(pol.named(pol.logits()), cshard),
                   donate_argnums=(1,) if donate_cache else ())
