"""Path-based PartitionSpec assignment for parameter / optimizer-state /
train-state pytrees. Rules follow sharding/policy.py fallback chains; any
leaf whose natural axis is not divisible by the model-axis size is
replicated (correct, just not TP-sharded — recorded in the dry-run report).
"""
from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding.policy import ShardingPolicy


def _path_str(path) -> str:
    parts = []
    for k in path:
        parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
    return "/".join(parts)


def _div(n: int, m: int) -> bool:
    return n > 0 and n % m == 0


def param_spec(pol: ShardingPolicy, path: str, shape) -> P:
    """Spec for one parameter leaf; `path` like 'blocks/0/attn/wq'."""
    m = pol._model()
    prepend = ("blocks/" in path or path.startswith("blocks")) or \
        ("encoder/blocks" in path)
    base = None
    name = path.split("/")[-1]
    parent = path.split("/")[-2] if "/" in path else ""

    if parent in ("attn", "cross"):
        base = {"wq": pol.wq(), "wk": pol.wkv(), "wv": pol.wkv(),
                "wo": pol.wo()}[name]
    elif parent == "mlp":
        base = {"w_gate": pol.w_ff_in(), "w_up": pol.w_ff_in(),
                "w_down": pol.w_ff_out()}[name]
    elif parent == "moe":
        base = {"router": P(None, None), "w_gate": pol.w_expert_in(),
                "w_up": pol.w_expert_in(), "w_down": pol.w_expert_out()}[name]
    elif parent == "mamba":
        d_inner_ok = _div(shape[-1], m) if name in (
            "in_proj", "conv_w", "dt_proj") else True
        mm = "model"
        specs = {
            "in_proj": P(None, mm if _div(shape[-1], m) else None),
            "conv_w": P(None, mm if _div(shape[-1], m) else None),
            "conv_b": P(mm if _div(shape[-1], m) else None),
            "x_proj": P(mm if _div(shape[0], m) else None, None),
            "dt_proj": P(None, mm if _div(shape[-1], m) else None),
            "dt_bias": P(mm if _div(shape[-1], m) else None),
            "A_log": P(mm if _div(shape[0], m) else None, None),
            "D": P(mm if _div(shape[-1], m) else None),
            "out_proj": P(mm if _div(shape[0], m) else None, None),
        }
        base = specs[name]
    elif parent == "embed" or name in ("tok", "unembed"):
        if name == "tok":
            base = P("model" if _div(shape[0], m) else None, None)
        else:
            base = P(None, "model" if _div(shape[-1], m) else None)
    else:
        base = P(*([None] * len(shape)))       # norms, frontend, misc

    if base is None:
        base = P(*([None] * len(shape)))
    spec = tuple(base)
    if prepend:
        spec = (None,) + spec                  # stacked period dim
    # rank-adjust (defensive: some leaves may differ in rank)
    if len(spec) > len(shape):
        spec = spec[:len(shape)]
    while len(spec) < len(shape):
        spec = spec + (None,)
    return P(*spec)


def opt_spec(pol: ShardingPolicy, path: str, shape) -> P:
    """Optimizer-state leaf: mirror the underlying param's spec.
    Adafactor factored leaves drop the corresponding dim."""
    parts = path.split("/")
    # state paths look like: m/<param path>, v/<param path>/vr, step ...
    if parts[-1] in ("vr", "vc"):
        ppath = "/".join(parts[1:-1])
        # infer the param spec at full rank, then drop a dim
        pspec = tuple(param_spec(pol, ppath, shape + (1,))
                      if parts[-1] == "vr" else
                      param_spec(pol, ppath,
                                 shape[:-1] + (1,) + shape[-1:]))
        if parts[-1] == "vr":
            return P(*pspec[:-1])
        return P(*(pspec[:-2] + pspec[-1:]))
    if parts[0] in ("m", "v"):
        return param_spec(pol, "/".join(parts[1:]), shape)
    return P(*([None] * len(shape)))


def tree_shardings(pol: ShardingPolicy, tree: Any, spec_fn) -> Any:
    """Pytree of NamedSharding for `tree` (arrays or ShapeDtypeStructs)."""
    def assign(path, leaf):
        spec = spec_fn(pol, _path_str(path), leaf.shape)
        return pol.named(spec)
    return jax.tree_util.tree_map_with_path(assign, tree)


def params_shardings(pol: ShardingPolicy, params: Any) -> Any:
    return tree_shardings(pol, params, param_spec)


def state_shardings(pol: ShardingPolicy, state: Any) -> Any:
    def assign(path, leaf):
        p = _path_str(path)
        if p.startswith("params/"):
            spec = param_spec(pol, p[len("params/"):], leaf.shape)
        elif p.startswith("opt/"):
            spec = opt_spec(pol, p[len("opt/"):], leaf.shape)
        elif p.startswith("err/"):
            spec = param_spec(pol, p[len("err/"):], leaf.shape)
        else:
            spec = P(*([None] * len(leaf.shape)))
        return pol.named(spec)
    return jax.tree_util.tree_map_with_path(assign, state)
