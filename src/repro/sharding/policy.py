"""Sharding policy: PartitionSpec derivation with divisibility fallbacks.

Mesh axes:
  single-pod: ("data", "model")            shape (16, 16)
  multi-pod : ("pod", "data", "model")     shape (2, 16, 16)

Fallback chains (see DESIGN.md):
  attention weights : n_heads -> head_dim -> replicate
  KV cache          : n_kv_heads -> seq pages -> replicate
  FFN               : d_ff ; MoE: experts (EP) ; embeddings: padded vocab
  batch             : ("pod","data") when divisible else replicate
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def mesh_axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_size(mesh: Mesh) -> int:
    n = 1
    for a in data_axes(mesh):
        n *= mesh_axis_size(mesh, a)
    return n


@dataclass(frozen=True)
class ShardingPolicy:
    """Resolves every tensor role in the system to a PartitionSpec."""
    mesh: Mesh
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    n_experts: int
    global_batch: int
    seq_len: int
    page_tokens: int = 16
    expert_bytes: int = 0            # total expert-param bytes (all layers)
    ep_hbm_budget: int = 8 << 30     # per-device budget before grid EP
    ep_mode_override: str = "auto"   # pin the mode (probes must keep the
                                     # production layout)

    # ---- helpers ----
    def _model(self) -> int:
        return mesh_axis_size(self.mesh, "model")

    def _div(self, n: int) -> bool:
        return n > 0 and n % self._model() == 0

    @property
    def batch_spec(self):
        axes = data_axes(self.mesh)
        if self.global_batch % data_size(self.mesh) == 0:
            return axes if len(axes) > 1 else axes[0]
        return None

    # how attention compute is split across "model"
    @property
    def attn_shard_mode(self) -> str:
        if self._div(self.n_heads):
            return "heads"
        if self._div(self.head_dim):
            return "head_dim"
        return "replicate"

    # how the KV *storage tier* is split across "model" (the CSD array)
    @property
    def kv_shard_mode(self) -> str:
        if self._div(self.n_kv_heads):
            return "kv_heads"
        if (self.seq_len // self.page_tokens) % self._model() == 0:
            return "seq"
        return "replicate"

    # ---- parameter specs ----
    def wq(self):   # [d, H, hd]
        m = self.attn_shard_mode
        return P(None, "model", None) if m == "heads" else (
            P(None, None, "model") if m == "head_dim" else P(None, None, None))

    def wkv(self):  # [d, KV, hd]
        m = self.attn_shard_mode
        if m == "heads" and self._div(self.n_kv_heads):
            return P(None, "model", None)
        if m == "head_dim":
            return P(None, None, "model")
        return P(None, None, None)

    def wo(self):   # [H, hd, d]
        m = self.attn_shard_mode
        return P("model", None, None) if m == "heads" else (
            P(None, "model", None) if m == "head_dim" else P(None, None, None))

    def w_ff_in(self):   # [d, f]
        return P(None, "model") if self._div(self.d_ff) else P(None, None)

    def w_ff_out(self):  # [f, d]
        return P("model", None) if self._div(self.d_ff) else P(None, None)

    def moe_mode(self) -> str:
        """How expert weights are laid out:
        'model': EP over the model axis only (small MoEs).
        'grid' : experts over `data` x d_ff over `model` — needed when
                 per-device expert bytes under model-only EP exceed HBM
                 (kimi-k2 1T, jamba 398B). See DESIGN.md.
        'replicate': no EP possible."""
        d_axis = mesh_axis_size(self.mesh, "data")
        m = self._model()
        model_ok = self._div(self.n_experts)
        grid_ok = (self.n_experts % d_axis == 0 and self._div(self.d_ff))
        if self.ep_mode_override == "model" and model_ok:
            return "model"
        if self.ep_mode_override == "grid" and grid_ok:
            return "grid"
        if model_ok and self.expert_bytes // m <= self.ep_hbm_budget:
            return "model"
        if grid_ok:
            return "grid"
        if model_ok:
            return "model"
        return "replicate"

    def w_expert_in(self):   # [E, d, f]
        mode = self.moe_mode()
        if mode == "grid":
            return P("data", None, "model")
        if mode == "model":
            return P("model", None, None)
        return P(None, None, "model") if self._div(self.d_ff) else P(None, None, None)

    def w_expert_out(self):  # [E, f, d]
        mode = self.moe_mode()
        if mode == "grid":
            return P("data", "model", None)
        if mode == "model":
            return P("model", None, None)
        return P(None, "model", None) if self._div(self.d_ff) else P(None, None, None)

    def embed(self):     # [V, d]
        return P("model", None)

    def mamba_inner(self):   # tensors with a d_inner dim at axis -1
        return P(None, "model")

    def norm(self):
        return P(None)

    # ---- activation specs ----
    def acts(self, *, heads: bool = False):   # [B, S, d] or [B, S, H, hd]
        b = self.batch_spec
        if heads:
            m = self.attn_shard_mode
            hspec = "model" if m == "heads" else None
            dspec = "model" if m == "head_dim" else None
            return P(b, None, hspec, dspec)
        return P(b, None, None)

    def tokens(self):    # [B, S] int32
        return P(self.batch_spec, None)

    # KV cache storage layout [B, KV, n_pages, page, hd] (token-indexed)
    def kv_pages(self):
        b = self.batch_spec
        m = self.kv_shard_mode
        if m == "kv_heads":
            return P(b, "model", None, None, None)
        if m == "seq":
            return P(b, None, "model", None, None)
        return P(b, None, None, None, None)

    # embedding-indexed K copy [B, KV, hd, S]
    def k_embed(self):
        b = self.batch_spec
        m = self.kv_shard_mode
        if m == "kv_heads":
            return P(b, "model", None, None)
        if m == "seq":
            return P(b, None, None, "model")
        return P(b, None, None, None)

    # mamba decode state [B, d_inner, N] — shard-resident, never moves
    def ssm_state(self):
        return P(self.batch_spec, "model", None)

    def conv_state(self):    # [B, conv, d_inner]
        return P(self.batch_spec, None, "model")

    def logits(self):        # [B, S, V]
        return P(self.batch_spec, None, "model")

    def named(self, spec) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def c(self, x, spec):
        """Apply a sharding constraint (no-op for None spec)."""
        if spec is None:
            return x
        return jax.lax.with_sharding_constraint(x, self.named(spec))


class NullPolicy:
    """Policy used off-mesh (smoke tests, single device): all no-ops."""
    def __getattr__(self, name):
        if name in ("batch_spec",):
            return None
        if name in ("attn_shard_mode",):
            return "replicate"
        if name in ("kv_shard_mode",):
            return "replicate"
        return lambda *a, **k: None

    def c(self, x, spec):  # noqa: D401
        return x


NULL = NullPolicy()


def policy_for(cfg, mesh: Optional[Mesh], shape) -> "ShardingPolicy | NullPolicy":
    if mesh is None:
        return NULL
    n_moe = sum(1 for i in range(cfg.n_layers) if cfg.is_moe_layer(i))
    expert_bytes = n_moe * cfg.n_experts * 3 * cfg.d_model * cfg.d_ff * 2
    return ShardingPolicy(
        mesh=mesh, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim or 0, d_ff=cfg.d_ff, n_experts=cfg.n_experts,
        global_batch=shape.global_batch, seq_len=shape.seq_len,
        page_tokens=cfg.sparf.page_tokens, expert_bytes=expert_bytes,
        ep_mode_override=getattr(cfg, "ep_mode", "auto"))
