"""Runtime subsystems: optimizers, checkpointing (atomic, keep-k, elastic
restore), deterministic data pipeline, gradient compression."""
import os

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.runtime import checkpoint as ckpt
from repro.runtime import compress
from repro.runtime.data import DataConfig, batch_at
from repro.runtime.optimizer import (OptConfig, adafactor_init,
                                     adafactor_update, adamw_init,
                                     adamw_update, lr_at)


# ---- optimizers -------------------------------------------------------------

@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_converges_quadratic(name):
    """Both optimizers drive a quadratic toward its minimum."""
    oc = OptConfig(name=name, lr=0.05, warmup_steps=5, total_steps=500,
                   weight_decay=0.0, clip_norm=100.0)
    target = jnp.asarray([1.5, -2.0, 0.5])
    params = {"w": jnp.zeros(3), "blocks": ({"a": jnp.zeros((2, 2))},)}
    init = adamw_init if name == "adamw" else adafactor_init
    update = adamw_update if name == "adamw" else adafactor_update
    state = init(oc, params)

    def loss(p):
        return (jnp.sum((p["w"] - target) ** 2)
                + jnp.sum((p["blocks"][0]["a"] - 1.0) ** 2))

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state, _ = update(oc, g, state, params)
    assert float(loss(params)) < 1e-2


def test_lr_schedule():
    oc = OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(lr_at(oc, jnp.asarray(0))) < 0.2
    assert abs(float(lr_at(oc, jnp.asarray(10))) - 1.0) < 0.15
    assert float(lr_at(oc, jnp.asarray(100))) < 0.05


# ---- checkpoint -------------------------------------------------------------

def test_checkpoint_roundtrip_and_keep_k(tmp_path):
    d = str(tmp_path)
    tree = {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                       "blocks": ({"a": jnp.ones((2,), jnp.bfloat16)},)},
            "step": jnp.asarray(7, jnp.int32)}
    for s in (1, 2, 3, 4):
        ckpt.save(d, s, tree, keep=2)
    assert ckpt.latest_step(d) == 4
    kept = [n for n in os.listdir(d) if n.startswith("step_")]
    assert len(kept) == 2
    restored = ckpt.restore(d, 4, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_checkpoint_atomicity(tmp_path):
    """A .tmp dir left behind by a crash is never considered a checkpoint."""
    d = str(tmp_path)
    tree = {"w": jnp.ones(3)}
    ckpt.save(d, 1, tree)
    os.makedirs(os.path.join(d, "step_00000002.tmp"))
    assert ckpt.latest_step(d) == 1


# ---- data -------------------------------------------------------------------

def test_data_deterministic_and_shardable():
    dc = DataConfig(vocab_size=1000, seq_len=32, global_batch=8, seed=3)
    b1 = batch_at(dc, step=5)
    b2 = batch_at(dc, step=5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # row-sliced host materializes exactly its rows
    dc_half = DataConfig(vocab_size=1000, seq_len=32, global_batch=8,
                         seed=3, row_start=0, rows=4)
    bh = batch_at(dc_half, step=5)
    assert bh["tokens"].shape == (4, 32)
    # shifted targets invariant
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["targets"][:, :-1])
    assert b1["tokens"].max() < 1000


# ---- compression ------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(n=st.integers(3, 700), scale=st.floats(1e-4, 1e3), seed=st.integers(0, 10))
def test_quantize_roundtrip_error_bound(n, scale, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n) * scale, jnp.float32)
    q, s, meta = compress.quantize(x)
    y = compress.dequantize(q, s, meta)
    blockmax = np.abs(np.asarray(x)).max() if n else 0
    assert np.abs(np.asarray(y - x)).max() <= blockmax / 127.0 + 1e-6


def test_error_feedback_preserves_signal():
    """Sum of compressed grads + final error == sum of raw grads (EF
    telescopes: nothing is lost, only delayed)."""
    rng = np.random.default_rng(0)
    grads = [jnp.asarray(rng.standard_normal(130), jnp.float32) * 0.01
             for _ in range(20)]
    err = jnp.zeros(130)
    sent = jnp.zeros(130)
    for g in grads:
        out, err = compress.compress_leaf(g, err)
        sent = sent + out
    total = sum(np.asarray(g) for g in grads)
    np.testing.assert_allclose(np.asarray(sent + err), total, atol=1e-5)
