"""Property tests on the FTL layout invariants (paper §IV-C)."""
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.configs.base import ModelConfig, SparFConfig
from repro.core.paged_kv import (init_layer_cache, local_positions,
                                 make_layout, write_prefill)


def _cfg(kv, hd, page):
    return ModelConfig(name="t", family="dense", n_layers=1,
                       d_model=kv * 2 * hd, n_heads=kv * 2, n_kv_heads=kv,
                       d_ff=8, vocab_size=8,
                       sparf=SparFConfig(page_tokens=page))


@settings(max_examples=25, deadline=None)
@given(kv=st.sampled_from([1, 2, 4, 8]),
       workers=st.sampled_from([1, 2, 4, 8, 16]),
       page=st.sampled_from([4, 8, 16]),
       n_pages_per=st.integers(1, 4))
def test_local_positions_partition_the_sequence(kv, workers, page,
                                                n_pages_per):
    """Workers' local position sets are disjoint and cover [0, max_seq):
    the strided stripe placement loses and duplicates nothing."""
    cfg = _cfg(kv, 8, page)
    layout = make_layout(cfg, page * n_pages_per * workers, workers)
    seen = []
    for stripe in range(layout.seq_shards):
        seen.append(np.asarray(local_positions(layout, stripe)))
    allpos = np.concatenate(seen)
    assert len(allpos) == layout.max_seq
    assert sorted(allpos.tolist()) == list(range(layout.max_seq))


@settings(max_examples=15, deadline=None)
@given(kv=st.sampled_from([2, 4]), workers=st.sampled_from([1, 2, 4, 8]),
       page=st.sampled_from([4, 8]), seed=st.integers(0, 5))
def test_write_prefill_roundtrip(kv, workers, page, seed):
    """Tokens written through the strided page layout are recoverable at
    their logical positions from the owning worker's shard."""
    cfg = _cfg(kv, 8, page)
    S = page * 4 * max(workers, 1)
    layout = make_layout(cfg, S, workers)
    B, hd = 2, 8
    k = jax.random.normal(jax.random.PRNGKey(seed), (B, S, kv, hd))
    v = jnp.zeros_like(k)
    cache = write_prefill(layout, init_layer_cache(layout, B, jnp.float32),
                          k, v, lengths=S)
    kp = np.asarray(cache["k_pages"])      # [B, W, kv_loc, P_loc, page, hd]
    ke = np.asarray(cache["k_embed"])      # [B, W, kv_loc, hd, S_loc]
    for w in range(layout.n_workers):
        kv_shard, stripe = w // layout.seq_shards, w % layout.seq_shards
        pos = np.asarray(local_positions(layout, stripe))
        flat = kp[:, w].reshape(B, layout.kv_loc, -1, hd)
        for h in range(layout.kv_loc):
            gh = kv_shard * layout.kv_loc + h
            np.testing.assert_allclose(flat[:, h], np.asarray(k)[:, pos, gh],
                                       atol=1e-6)
            # dual-indexed copy agrees with the token-indexed copy
            np.testing.assert_allclose(ke[:, w, h].swapaxes(-1, -2),
                                       flat[:, h], atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(kv=st.sampled_from([1, 2, 4, 8]),
       workers=st.sampled_from([1, 2, 4, 8, 16]))
def test_layout_shards_are_consistent(kv, workers):
    cfg = _cfg(kv, 8, 8)
    layout = make_layout(cfg, 128 * workers, workers)
    assert layout.kv_shards * layout.seq_shards == layout.n_workers
    assert layout.kv_shards * layout.kv_loc == layout.n_kv_heads
    assert layout.pages_loc * layout.seq_shards == layout.n_pages
