import os
import sys

# Tests see ONE device (dry-run sets its own flags in a separate process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def assert_tree_finite(tree):
    import jax.numpy as jnp
    for leaf in jax.tree.leaves(tree):
        assert not bool(jnp.isnan(jnp.asarray(leaf, jnp.float32)).any())
