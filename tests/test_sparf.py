"""SparF algorithm properties (paper Alg.1) + hypothesis property tests on
the paged-KV invariants."""
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.configs.base import ModelConfig, SparFConfig
from repro.core import baselines
from repro.core.offload import decode_attention
from repro.core.paged_kv import (init_layer_cache, make_layout,
                                 write_prefill)
from repro.sharding.policy import NULL


def _mk(B=2, S=64, KV=4, H=8, hd=16, r=8, k=32, page=4, seed=0):
    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=H * hd,
                      n_heads=H, n_kv_heads=KV, d_ff=16, vocab_size=64,
                      sparf=SparFConfig(rank_r=r, top_k=k, page_tokens=page))
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    k_ = jax.random.normal(ks[0], (B, S, KV, hd))
    v_ = jax.random.normal(ks[1], (B, S, KV, hd))
    q_ = jax.random.normal(ks[2], (B, H, hd))
    return cfg, q_, k_, v_


def _cache(cfg, k, v, length, n_workers=1):
    S = k.shape[1]
    layout = make_layout(cfg, S, n_workers)
    c = write_prefill(layout, init_layer_cache(layout, k.shape[0],
                                               jnp.float32), k, v,
                      lengths=length)
    return layout, c


def test_sparf_full_k_equals_dense():
    """With top_k = S and r = hd, SparF must equal dense attention."""
    cfg, q, k, v = _mk(r=16, k=64)
    length = 50
    layout, cache = _cache(cfg, k, v, length)
    dense = decode_attention(cfg, NULL, layout, q, cache, length,
                             impl="insti_dense")
    sparf = decode_attention(cfg, NULL, layout, q, cache, length,
                             impl="insti_sparf")
    np.testing.assert_allclose(np.asarray(sparf), np.asarray(dense),
                               atol=1e-5)


def test_sparf_error_decreases_with_k():
    cfg, q, k, v = _mk()
    length = 60
    errs = []
    for kk in (8, 16, 32, 64):
        c = cfg.replace(sparf=SparFConfig(rank_r=8, top_k=kk, page_tokens=4))
        layout, cache = _cache(c, k, v, length)
        dense = decode_attention(c, NULL, layout, q, cache, length,
                                 impl="insti_dense")
        sparf = decode_attention(c, NULL, layout, q, cache, length,
                                 impl="insti_sparf")
        errs.append(float(jnp.mean(jnp.abs(sparf - dense))))
    assert errs[-1] <= errs[0]
    assert errs[-1] < 1e-5          # k = S exact


def test_sparf_beats_local_window():
    """Fig. 11 qualitative claim: SparF error << local-window error at the
    same budget (averaged over heads/batch)."""
    cfg, q, k, v = _mk(S=128, k=32, seed=3)
    length = 120
    layout, cache = _cache(cfg, k, v, length)
    dense = decode_attention(cfg, NULL, layout, q, cache, length,
                             impl="insti_dense")
    sparf = decode_attention(cfg, NULL, layout, q, cache, length,
                             impl="insti_sparf")
    loc = baselines.local_decode(q, k, v, length, keep=32)
    err_sparf = float(jnp.mean(jnp.abs(sparf - dense)))
    err_local = float(jnp.mean(jnp.abs(loc - dense)))
    assert err_sparf < err_local


def test_sparf_matches_vanilla_sparq():
    """SparF == SparQ in math (page structure only changes the access
    pattern)."""
    cfg, q, k, v = _mk(S=64, k=16, r=8)
    length = 64
    layout, cache = _cache(cfg, k, v, length)
    sparf = decode_attention(cfg, NULL, layout, q, cache, length,
                             impl="insti_sparf")
    v_mean = jnp.mean(v, axis=1)
    sparq = baselines.sparq_decode(q, k, v, length, r=8, keep=16,
                                   v_mean=v_mean)
    np.testing.assert_allclose(np.asarray(sparf), np.asarray(sparq),
                               atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(
    S=st.sampled_from([32, 64, 128]),
    KV=st.sampled_from([1, 2, 4]),
    G=st.sampled_from([1, 2]),
    page=st.sampled_from([4, 8, 16]),
    frac=st.floats(0.2, 1.0),
    seed=st.integers(0, 5),
)
def test_sparf_alpha_and_shape_properties(S, KV, G, page, frac, seed):
    """Property: SparF output is finite, correctly shaped, and is a convex
    combination (alpha in [0,1]) of exact attention and mean-V — so its
    norm is bounded by max(|attn|, |v_mean|) * (1 + eps)."""
    H = KV * G
    hd = 16
    cfg, q, k, v = _mk(B=1, S=S, KV=KV, H=H, hd=hd,
                       r=8, k=max(4, int(S * 0.25)), page=page, seed=seed)
    length = max(2, int(S * frac))
    layout, cache = _cache(cfg, k, v, length)
    out = decode_attention(cfg, NULL, layout, q, cache, length,
                           impl="insti_sparf")
    assert out.shape == q.shape
    assert bool(jnp.isfinite(out).all())
    vmax = float(jnp.max(jnp.abs(v[:, :length])))
    assert float(jnp.max(jnp.abs(out))) <= vmax + 1e-4


@settings(max_examples=15, deadline=None)
@given(
    S=st.sampled_from([32, 64]),
    KV=st.sampled_from([2, 4]),
    page=st.sampled_from([4, 8]),
    seed=st.integers(0, 3),
)
def test_dense_paged_equals_flat_oracle(S, KV, page, seed):
    """Property: the paged store + dense decode == flat attention oracle for
    any (S, KV, page) combination and any live length."""
    G = 2
    H = KV * G
    cfg, q, k, v = _mk(B=2, S=S, KV=KV, H=H, hd=16, page=page, seed=seed)
    length = S - 3
    layout, cache = _cache(cfg, k, v, length)
    out = decode_attention(cfg, NULL, layout, q, cache, length,
                           impl="insti_dense")
    oracle = baselines.dense_decode(q, k, v, length)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               atol=1e-5)
