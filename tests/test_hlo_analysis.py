"""Unit tests for the HLO collective-bytes parser (roofline input)."""
from repro.utils.hlo import collective_bytes, collective_counts, shape_bytes


HLO = """
  %ag = f32[32,64]{1,0} all-gather(%x), channel_id=1, replica_groups=[4,2]<=[2,4]T(1,0), dimensions={0}
  %ar = bf16[128]{0} all-reduce(%y), replica_groups=[2,4]<=[8], to_apply=%add
  %rs = f32[16,8]{1,0} reduce-scatter(%z), replica_groups=[2,4]<=[8], dimensions={0}
  %a2a = f32[8,8]{1,0} all-to-all(%w), replica_groups=[1,8]<=[8], dimensions={0}
  %cp = s32[4]{0} collective-permute(%v), source_target_pairs={{0,1}}
  %ags = (f32[4,4]{1,0}, u32[]) all-gather-start(%q), replica_groups=[2,2]<=[4], dimensions={1}
  %agd = f32[4,4]{1,0} all-gather-done(%ags)
  %noise = f32[99]{0} add(%a, %b)
"""


def test_shape_bytes():
    assert shape_bytes("f32", "32,64") == 32 * 64 * 4
    assert shape_bytes("bf16", "128") == 256
    assert shape_bytes("pred", "") == 1


def test_collective_bytes_per_kind():
    out = collective_bytes(HLO)
    # all-gather: result 8192 B / 2 participants -> 4096 operand;
    # -start tuple (f32[4,4] + u32[]) = 68 B / 2 participants -> 34
    assert out["all-gather"] == 8192 // 2 + 68 // 2
    assert out["all-reduce"] == 128 * 2
    # reduce-scatter: operand = result * participants
    assert out["reduce-scatter"] == 16 * 8 * 4 * 4
    assert out["all-to-all"] == 8 * 8 * 4
    assert out["collective-permute"] == 16
    assert out["total"] == sum(
        out[k] for k in ("all-gather", "all-reduce", "reduce-scatter",
                         "all-to-all", "collective-permute"))


def test_counts_skip_done_ops():
    c = collective_counts(HLO)
    assert c["all-gather"] == 2        # plain + -start, not -done
    assert c["all-reduce"] == 1
