"""Operator-placement planner (paper §III-B / Fig. 6) — the split must be
*derived*, and must flip when the hardware premise flips."""
import dataclasses

import pytest

from repro.core.engine import (CSD_ZYNQ, GPU_A6000, opt13b_operators,
                               paper_plan, plan)


def test_paper_split_is_recovered():
    got = {(r["op"], r["phase"]): r["placement"] for r in paper_plan(64)}
    assert got == {("QKV/O-Proj+FFN", "prefill"): "compute",
                   ("Attention", "prefill"): "compute",
                   ("QKV/O-Proj+FFN", "decode"): "compute",
                   ("Logit+Attend", "decode"): "storage"}


def test_decode_attention_moves_back_when_egress_is_fast():
    """If the storage medium could egress at full link speed (i.e. the
    PCIe bottleneck the paper targets did not exist), offloading decode
    attention to a 100x weaker engine would no longer win."""
    fast_storage = dataclasses.replace(CSD_ZYNQ, bulk_bw=64e9, link_bw=64e9)
    rows = plan(opt13b_operators(64), GPU_A6000, fast_storage)
    got = {(r["op"], r["phase"]): r["placement"] for r in rows}
    assert got[("Logit+Attend", "decode")] == "compute"


def test_prefill_never_offloaded_even_with_slow_egress():
    """Prefill attention is compute-intense; the CSD's weak FLOPs keep it
    on the GPU regardless (paper: 'prefill-phase attention should also
    remain on the GPU')."""
    rows = plan(opt13b_operators(256), GPU_A6000, CSD_ZYNQ)
    got = {(r["op"], r["phase"]): r["placement"] for r in rows}
    assert got[("Attention", "prefill")] == "compute"


@pytest.mark.parametrize("batch", [4, 32, 256])
def test_decode_attention_intensity_is_constant(batch):
    """Decode attention AI == 1 independent of batch (the paper's core
    observation: GeMV cannot be batched into compute-bound territory)."""
    ops = {(o.name, o.phase): o for o in opt13b_operators(batch)}
    assert ops[("Logit+Attend", "decode")].intensity == pytest.approx(1.0)