"""End-to-end behaviour tests: every assigned architecture instantiates a
reduced config, runs one train step and a prefill+decode step on CPU, and
produces finite outputs with the right shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig, get_arch, list_archs
from repro.core.paged_kv import make_layout
from repro.models.model_zoo import (build, forward, init_cache, init_params,
                                    make_inputs)
from repro.runtime.optimizer import OptConfig
from repro.runtime.train_state import init_train_state, make_train_step
from repro.sharding.policy import NULL

ARCHS = [a for a in list_archs()]


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = build(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    oc = OptConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    state = init_train_state(cfg, params, oc)
    step = make_train_step(cfg, NULL, oc)
    batch = make_inputs(cfg, ShapeConfig("t", 32, 2, "train"), key)
    state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state["step"]) == 1
    # loss decreases over a few steps on repeated data (sanity, not perf)
    l0 = float(metrics["loss"])
    for _ in range(3):
        state, metrics = jax.jit(step)(state, batch)
    assert float(metrics["loss"]) < l0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode(arch):
    cfg = build(arch, smoke=True).replace(max_seq=64)
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    B, S = 2, 16
    batch = make_inputs(cfg, ShapeConfig("t", S, B, "prefill"), key)
    layout = make_layout(cfg, cfg.max_seq, 1)
    logits, _, cache = forward(cfg, NULL, params, batch, "prefill",
                               layout=layout, length=S)
    assert logits.shape == (B, S, cfg.padded_vocab)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    dlogits, _, cache = forward(cfg, NULL, params, {"token": tok}, "decode",
                                cache=cache, layout=layout)
    assert dlogits.shape == (B, 1, cfg.padded_vocab)
    assert not bool(jnp.isnan(dlogits.astype(jnp.float32)).any())
    assert int(cache["length"]) == S + 1


@pytest.mark.parametrize("arch", ["glm4-9b", "jamba-1.5-large-398b",
                                  "whisper-base", "falcon-mamba-7b",
                                  "qwen3-moe-30b-a3b", "opt13b"])
def test_decode_matches_teacher_forcing(arch):
    """prefill(S-1) + decode(1) logits == causal full-forward logits at S-1,
    in f32 / dropless settings."""
    cfg = build(arch, smoke=True).replace(
        attention_impl="insti_dense", max_seq=64, dtype="float32",
        capacity_factor=100.0)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, S = 2, 24
    batch = make_inputs(cfg, ShapeConfig("t", S, B, "prefill"), key)
    full_logits, _, _ = forward(cfg, NULL, params, batch, "train")
    bp = dict(batch)
    bp["tokens"] = batch["tokens"][:, :S - 1]
    layout = make_layout(cfg, cfg.max_seq, 1)
    pre, _, cache = forward(cfg, NULL, params, bp, "prefill", layout=layout,
                            length=S - 1)
    np.testing.assert_allclose(np.float32(pre),
                               np.float32(full_logits[:, :S - 1]),
                               atol=2e-4, rtol=1e-3)
    dec, _, _ = forward(cfg, NULL, params,
                        {"token": batch["tokens"][:, S - 1:S]}, "decode",
                        cache=cache, layout=layout)
    np.testing.assert_allclose(np.float32(dec[:, 0]),
                               np.float32(full_logits[:, S - 1]),
                               atol=2e-4, rtol=1e-3)


def test_generation_deterministic():
    cfg = build("minitron-8b", smoke=True).replace(max_seq=64)
    from repro.serving.session import Session
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    sess = Session(cfg, params, max_seq=64)
    batch = make_inputs(cfg, ShapeConfig("t", 8, 2, "prefill"), key)
    out1 = sess.generate(batch, 6)
    sess2 = Session(cfg, params, max_seq=64)
    out2 = sess2.generate(batch, 6)
    assert out1.shape == (2, 6)
    np.testing.assert_array_equal(out1, out2)
