"""FTL page retirement (core.paged_kv.evict_pages): zero-movement eviction
must behave exactly like attention over the retained tokens."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, SparFConfig
from repro.core import baselines
from repro.core.offload import decode_attention
from repro.core.paged_kv import (evict_pages, init_layer_cache, make_layout,
                                 write_prefill)
from repro.sharding.policy import NULL


def _setup(S=64, KV=2, G=2, hd=16, page=8, seed=0):
    H = KV * G
    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=H * hd,
                      n_heads=H, n_kv_heads=KV, d_ff=8, vocab_size=8,
                      sparf=SparFConfig(rank_r=hd, top_k=S, page_tokens=page))
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    k = jax.random.normal(ks[0], (2, S, KV, hd))
    v = jax.random.normal(ks[1], (2, S, KV, hd))
    q = jax.random.normal(ks[2], (2, H, hd))
    layout = make_layout(cfg, S, 1)
    cache = write_prefill(layout, init_layer_cache(layout, 2, jnp.float32),
                          k, v, lengths=S)
    return cfg, layout, cache, q, k, v


@pytest.mark.parametrize("impl", ["insti_dense", "insti_sparf"])
def test_evict_middle_pages_matches_masked_oracle(impl):
    S, page = 64, 8
    cfg, layout, cache, q, k, v = _setup(S=S, page=page)
    keep = np.ones(S // page, bool)
    keep[2:4] = False                      # retire pages 2-3 (tokens 16..31)
    cache = evict_pages(layout, cache, keep)
    out = decode_attention(cfg, NULL, layout, q, cache, S, impl=impl)
    # oracle: rank retained tokens high, evicted low
    scores = jnp.where(jnp.repeat(jnp.asarray(keep), page), 1.0, -1e30)
    scores = jnp.broadcast_to(scores, (2, 2, 2, S))
    oracle = baselines.topk_mask_decode(q, k, v, S, int(keep.sum()) * page,
                                        scores)
    tol = 1e-5 if impl == "insti_dense" else 2e-2  # sparf adds (1-a)v̄, a~1
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               atol=tol, rtol=tol)


def test_evict_nothing_is_identity():
    cfg, layout, cache, q, k, v = _setup()
    base = decode_attention(cfg, NULL, layout, q, cache, 64,
                            impl="insti_dense")
    cache2 = evict_pages(layout, cache, np.ones(64 // 8, bool))
    out = decode_attention(cfg, NULL, layout, q, cache2, 64,
                           impl="insti_dense")
    np.testing.assert_array_equal(np.asarray(base), np.asarray(out))


def test_evict_is_metadata_only():
    """Eviction must not touch the stored pages (zero write amplification)."""
    cfg, layout, cache, *_ = _setup()
    keep = np.ones(8, bool)
    keep[0] = False
    cache2 = evict_pages(layout, cache, keep)
    for k_ in ("k_pages", "v_pages", "k_embed", "block_table"):
        np.testing.assert_array_equal(np.asarray(cache[k_]),
                                      np.asarray(cache2[k_]))
    assert not bool(cache2["page_valid"].all())
