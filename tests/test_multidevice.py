"""Multi-device integration tests. These spawn subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count so the main pytest process
keeps seeing 1 device (per the dry-run contract)."""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(script: str, n_devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


def test_offloaded_attention_multiworker_matches_oracle():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.core.paged_kv import make_layout, init_layer_cache, write_prefill
from repro.core.offload import decode_attention
from repro.configs.base import ModelConfig, SparFConfig, ShapeConfig
from repro.sharding.policy import policy_for
from repro.core.baselines import dense_decode

cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                  n_heads=8, n_kv_heads=4, d_ff=128, vocab_size=256,
                  sparf=SparFConfig(rank_r=8, top_k=64, page_tokens=4))
B, S = 2, 64
k = jax.random.normal(jax.random.PRNGKey(0), (B, S, 4, 8))
v = jax.random.normal(jax.random.PRNGKey(1), (B, S, 4, 8))
q = jax.random.normal(jax.random.PRNGKey(2), (B, 8, 8))
mesh = Mesh(np.array(jax.devices()).reshape(1, 8), ("data", "model"))
pol = policy_for(cfg, mesh, ShapeConfig("t", S, B, "decode"))
layout = make_layout(cfg, S, 8)
cache = write_prefill(layout, init_layer_cache(layout, B, jnp.float32),
                      k, v, lengths=50)
oracle = dense_decode(q, k, v, 50)
for impl in ("insti_dense", "flexgen_like", "insti_sparf"):
    out = jax.jit(lambda q, c: decode_attention(
        cfg, pol, layout, q, c, 50, impl=impl))(q, cache)
    err = float(jnp.max(jnp.abs(out - oracle)))
    tol = 1e-4 if impl != "insti_sparf" else 1e-3   # top_k=S: near-exact
    assert err < tol, (impl, err)
print("ok")
""")


def test_sharded_train_step_matches_single_device():
    """Same batch, same init: loss on a (2,4) mesh == single-device loss."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs.base import ShapeConfig
from repro.models.model_zoo import build, init_params, make_inputs
from repro.runtime.optimizer import OptConfig
from repro.runtime.train_state import init_train_state, make_train_step
from repro.sharding.policy import NULL, policy_for

cfg = build("minitron-8b", smoke=True).replace(
    dtype="float32", n_heads=4, n_kv_heads=2)
key = jax.random.PRNGKey(0)
params = init_params(cfg, key)
oc = OptConfig(lr=1e-3, warmup_steps=2, total_steps=10)
batch = make_inputs(cfg, ShapeConfig("t", 16, 8, "train"), key)
step1 = make_train_step(cfg, NULL, oc)
s1 = init_train_state(cfg, params, oc)
_, m1 = jax.jit(step1)(s1, batch)

mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
pol = policy_for(cfg, mesh, ShapeConfig("t", 16, 8, "train"))
step2 = make_train_step(cfg, pol, oc)
s2 = init_train_state(cfg, params, oc)
_, m2 = jax.jit(step2)(s2, batch)
assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4, (m1, m2)
print("ok", float(m1["loss"]), float(m2["loss"]))
""")


def test_moe_grid_ep_matches_local():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.moe import apply_moe, moe_init
from repro.sharding.policy import NULL, policy_for
import dataclasses

cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                  n_experts=8, experts_per_token=2, capacity_factor=100.0)
p = moe_init(jax.random.PRNGKey(0), 32, 64, 8, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32))
out_ref, aux_ref = apply_moe(cfg, NULL, p, x)
mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
shape = ShapeConfig("t", 8, 4, "train")
pol = policy_for(cfg, mesh, shape)
# force grid mode by zeroing the HBM budget
pol = dataclasses.replace(pol, ep_hbm_budget=0)
assert pol.moe_mode() == "grid", pol.moe_mode()
out_g, aux_g = jax.jit(lambda x, p: apply_moe(cfg, pol, p, x))(x, p)
np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_ref),
                           atol=2e-5, rtol=1e-4)
# model-only EP too
pol2 = dataclasses.replace(pol, ep_hbm_budget=1 << 60)
assert pol2.moe_mode() == "model"
out_m, _ = jax.jit(lambda x, p: apply_moe(cfg, pol2, p, x))(x, p)
np.testing.assert_allclose(np.asarray(out_m), np.asarray(out_ref),
                           atol=2e-5, rtol=1e-4)
print("ok")
""")


def test_elastic_remesh_restore(tmp_path):
    _run(f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs.base import ShapeConfig
from repro.models.model_zoo import build, init_params, make_inputs
from repro.runtime import checkpoint as ckpt
from repro.runtime.elastic import viable_mesh, remesh_and_restore
from repro.runtime.optimizer import OptConfig
from repro.runtime.train_state import init_train_state, make_train_step
from repro.sharding.params import state_shardings
from repro.sharding.policy import policy_for

cfg = build("minitron-8b", smoke=True).replace(dtype="float32",
                                               n_heads=4, n_kv_heads=2)
key = jax.random.PRNGKey(0)
params = init_params(cfg, key)
oc = OptConfig(lr=1e-3, warmup_steps=2, total_steps=10)
shape = ShapeConfig("t", 16, 8, "train")
mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
pol = policy_for(cfg, mesh, shape)
state = init_train_state(cfg, params, oc)
step = make_train_step(cfg, pol, oc)
batch = make_inputs(cfg, shape, key)
state, m_before = jax.jit(step)(state, batch)
ckpt.save({str(tmp_path)!r}, 1, state)

# 'lose' 4 devices -> remesh to (1,4) and restore
survivors = jax.devices()[:4]
new_mesh = viable_mesh(survivors, model_parallelism=4)
new_pol = policy_for(cfg, new_mesh, shape)
restored, step_no = remesh_and_restore(
    {str(tmp_path)!r}, state, new_mesh,
    lambda mesh, like: state_shardings(new_pol, like))
assert step_no == 1
step2 = make_train_step(cfg, new_pol, oc)
restored2, m_after = jax.jit(step2)(restored, batch)
assert np.isfinite(float(m_after["loss"]))
# resumed step must match what the original mesh would have produced
state2, m_orig = jax.jit(step)(state, batch)
assert abs(float(m_after["loss"]) - float(m_orig["loss"])) < 1e-4
print("ok")
""")
