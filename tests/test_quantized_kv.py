"""fp8 KV storage (beyond-paper, §Perf iteration 6): correctness bounds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig
from repro.core.paged_kv import make_layout
from repro.models.model_zoo import build, forward, init_params, make_inputs
from repro.sharding.policy import NULL


@pytest.mark.parametrize("impl", ["insti_dense", "insti_sparf"])
def test_fp8_kv_close_to_bf16(impl):
    cfg0 = build("glm4-9b", smoke=True).replace(
        max_seq=64, dtype="float32", attention_impl=impl)
    params = init_params(cfg0, jax.random.PRNGKey(0))
    batch = make_inputs(cfg0, ShapeConfig("t", 24, 2, "prefill"),
                        jax.random.PRNGKey(0))
    probs = {}
    for kvd in ("", "float8_e4m3fn"):
        cfg = cfg0.replace(kv_dtype=kvd)
        layout = make_layout(cfg, cfg.max_seq, 1)
        _, _, cache = forward(cfg, NULL, params, batch, "prefill",
                              layout=layout, length=24)
        d, _, _ = forward(cfg, NULL, params,
                          {"token": batch["tokens"][:, :1]}, "decode",
                          cache=cache, layout=layout)
        probs[kvd] = np.float32(jax.nn.softmax(d[:, 0], -1))
    err = np.abs(probs[""] - probs["float8_e4m3fn"]).max()
    assert err < 0.05, err


def test_fp8_kv_cache_is_half_size():
    cfg = build("glm4-9b", smoke=True).replace(max_seq=64)
    from repro.models.transformer import init_cache
    c16 = init_cache(cfg, 2, 64, 1)
    c8 = init_cache(cfg.replace(kv_dtype="float8_e4m3fn"), 2, 64, 1)
    b16 = sum(x.size * x.dtype.itemsize
              for x in jax.tree.leaves(c16["layers"]))
    b8 = sum(x.size * x.dtype.itemsize
             for x in jax.tree.leaves(c8["layers"]))
    assert b8 < 0.6 * b16
