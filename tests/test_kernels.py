"""Per-kernel validation: shape/dtype sweeps, assert_allclose against the
ref.py pure-jnp oracles (kernels run in interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: dict(atol=1e-5, rtol=1e-5),
       jnp.bfloat16: dict(atol=2e-2, rtol=2e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,Sq,Sk,hd,bq,bk", [
    (1, 2, 128, 128, 64, 64, 64),
    (2, 1, 256, 256, 32, 128, 128),
    (1, 4, 64, 256, 128, 64, 64),     # cross-length (kv longer)
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(B, H, Sq, Sk, hd, bq, bk, dtype, causal):
    q = _rand(0, (B, H, Sq, hd), dtype)
    k = _rand(1, (B, H, Sk, hd), dtype)
    v = _rand(2, (B, H, Sk, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk)
    r = ref.flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.float32(out), np.float32(r),
                               **TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,KV,G,P,page,hd,length", [
    (1, 2, 2, 8, 8, 64, 50),
    (2, 1, 4, 16, 4, 32, 64),
    (1, 4, 1, 4, 16, 128, 17),
])
def test_paged_attention(B, KV, G, P, page, hd, length, dtype):
    kp = _rand(3, (B, KV, P, page, hd), dtype)
    vp = _rand(4, (B, KV, P, page, hd), dtype)
    q = _rand(5, (B, KV, G, hd), dtype)
    perm = jax.random.permutation(jax.random.PRNGKey(6), P)
    bt = jnp.broadcast_to(perm, (B, KV, P)).astype(jnp.int32)
    out = ops.paged_attention(q, kp, vp, bt, length)
    r = ref.paged_attention(q, kp, vp, bt, length)
    np.testing.assert_allclose(np.float32(out), np.float32(r),
                               **TOL[dtype])


def test_paged_attention_block_table_permutation_invariance():
    """FTL property: physically permuting pages + updating the table leaves
    the result unchanged."""
    B, KV, G, P, page, hd, length = 1, 2, 2, 8, 8, 64, 60
    kp = _rand(7, (B, KV, P, page, hd), jnp.float32)
    vp = _rand(8, (B, KV, P, page, hd), jnp.float32)
    q = _rand(9, (B, KV, G, hd), jnp.float32)
    bt_id = jnp.broadcast_to(jnp.arange(P), (B, KV, P)).astype(jnp.int32)
    base = ops.paged_attention(q, kp, vp, bt_id, length)
    perm = jax.random.permutation(jax.random.PRNGKey(10), P)
    # move logical page i to physical slot perm[i]; table points at perm
    bt2 = jnp.broadcast_to(perm, (B, KV, P)).astype(jnp.int32)
    kp3 = jnp.zeros_like(kp).at[:, :, perm].set(kp)
    vp3 = jnp.zeros_like(vp).at[:, :, perm].set(vp)
    moved = ops.paged_attention(q, kp3, vp3, bt2, length)
    np.testing.assert_allclose(np.asarray(moved), np.asarray(base),
                               atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("S,KV,G,page,r,k", [
    (64, 2, 2, 8, 16, 16),
    (128, 1, 4, 16, 8, 32),
    (64, 4, 1, 4, 32, 64),     # k = S: exact
])
def test_sparf_kernels_match_core(S, KV, G, page, r, k, dtype):
    from repro.configs.base import SparFConfig
    from repro.core.paged_kv import KVLayout
    from repro.core.sparf import combine_sparf, sparf_worker
    B, hd = 2, 64
    P = S // page
    kp = _rand(11, (B, KV, P, page, hd), dtype)
    vp = _rand(12, (B, KV, P, page, hd), dtype)
    q = _rand(13, (B, KV, G, hd), dtype)
    length = S - 5
    ke = kp.reshape(B, KV, S, hd).swapaxes(-1, -2)
    v_sum = jnp.sum(jnp.float32(vp.reshape(B, KV, S, hd))[:, :, :length], 2)
    bt = jnp.broadcast_to(jnp.arange(P), (B, KV, P)).astype(jnp.int32)
    out = ops.sparf_attention(q, kp, vp, ke, bt, v_sum, length,
                              rank_r=r, top_k=k)
    layout = KVLayout(n_kv_heads=KV, head_dim=hd, page=page, n_pages=P,
                      n_workers=1, kv_shards=1, seq_shards=1)
    part = sparf_worker(layout, SparFConfig(rank_r=r, top_k=k,
                                            page_tokens=page),
                        q, kp, vp, ke, bt, 0, length)
    rr = combine_sparf(part, v_sum / length)
    np.testing.assert_allclose(np.float32(out), np.float32(rr),
                               atol=5e-3 if dtype == jnp.bfloat16 else 1e-5,
                               rtol=1e-2 if dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("B,T,D,N,chunk", [
    (1, 64, 8, 8, 16), (2, 128, 16, 16, 64), (1, 32, 4, 4, 32),
])
def test_mamba_scan(B, T, D, N, chunk, dtype):
    ab = jax.random.uniform(jax.random.PRNGKey(14), (B, T, D, N),
                            minval=0.5, maxval=0.999).astype(dtype)
    bx = (_rand(15, (B, T, D, N), dtype) * 0.1).astype(dtype)
    ct = _rand(16, (B, T, N), dtype)
    out = ops.mamba_scan(ab, bx, ct, chunk=chunk)
    r, _ = ref.mamba_scan(ab, bx, ct)
    np.testing.assert_allclose(np.float32(out), np.float32(r),
                               atol=1e-5, rtol=1e-4)
