"""Launcher CLIs end-to-end (subprocess, multi-device): train with
checkpoint+resume and serve with the in-storage path."""
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(args, n_devices=4, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-m"] + args, env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, r.stdout[-2000:] + "\n" + r.stderr[-2000:]
    return r.stdout


def test_train_cli_with_checkpoint_resume(tmp_path):
    ck = str(tmp_path / "run")
    out = _run(["repro.launch.train", "--arch", "minitron-8b", "--smoke",
                "--steps", "6", "--batch", "4", "--seq", "32",
                "--ckpt", ck, "--ckpt-every", "3",
                "--model-parallel", "2"])
    assert "step 5:" in out and "done" in out
    # resume continues from the checkpoint, not step 0
    out2 = _run(["repro.launch.train", "--arch", "minitron-8b", "--smoke",
                 "--steps", "8", "--batch", "4", "--seq", "32",
                 "--ckpt", ck, "--ckpt-every", "3",
                 "--model-parallel", "2"])
    assert "resumed from step 6" in out2
    assert "step 0:" not in out2


def test_serve_cli_offloaded(tmp_path):
    out = _run(["repro.launch.serve", "--arch", "glm4-9b", "--smoke",
                "--batch", "4", "--prompt-len", "16", "--gen", "4",
                "--impl", "insti_sparf", "--model-parallel", "4"])
    assert "generated (4, 4)" in out


def test_train_cli_gradient_compression(tmp_path):
    out = _run(["repro.launch.train", "--arch", "glm4-9b", "--smoke",
                "--steps", "3", "--batch", "2", "--seq", "32",
                "--compress-grads"])
    assert "done" in out
