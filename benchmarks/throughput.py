"""Paper Fig. 12/13: end-to-end decode throughput vs batch size, 1 and 2
SSDs/CSDs, for all five systems — from the calibrated hardware model.
Derived column checks the paper's headline ratios."""
from __future__ import annotations

from benchmarks.hwmodel import LM, SYSTEMS, throughput, with_drives

BATCHES = (4, 8, 16, 32, 64, 128, 256)


def table(n_drives: int = 1):
    lm = LM()
    rows = {}
    for name, sys in SYSTEMS.items():
        sys = with_drives(sys, n_drives)
        rows[name] = [throughput(sys, lm, b) for b in BATCHES]
    return rows


def run(report):
    for nd in (1, 2):
        rows = table(nd)
        for name, tps in rows.items():
            for b, t in zip(BATCHES, tps):
                report(f"throughput/{nd}ssd/{name}/bs{b}",
                       1e6 / t if t else float("inf"),
                       f"{t:.2f} tok/s")
        # headline ratios (paper VI-C)
        fg = rows["FlexGen"]
        sp = rows["InstI-SparF"]
        di = rows["InstI-Dense"]
        ds = rows["DeepSpeed"]
        fq = rows["FlexGen-SparQ"]
        best = lambda xs: max([v for v in xs if v] or [1e-9])
        if nd == 1:
            report("ratio/InstI-SparF_bs256_vs_FlexGen_best", 0,
                   f"{sp[-1] / best(fg):.1f}x (paper: 11.1x)")
            report("ratio/InstI-Dense_vs_FlexGen_bs64", 0,
                   f"{di[BATCHES.index(64)] / fg[BATCHES.index(64)]:.2f}x "
                   f"(paper: 6.85x)")
            report("ratio/SparF_vs_Dense_bs256", 0,
                   f"{sp[-1] / di[-1]:.2f}x (paper: 2.08x)")
            report("ratio/InstI_bs256_vs_DeepSpeed_best", 0,
                   f"{(di[-1] / best(ds) - 1) * 100:+.1f}% (paper: +4.6%)")
        else:
            report("ratio/InstI_bs256_vs_FlexGen_best_2ssd", 0,
                   f"{di[-1] / best(fg):.1f}x (paper: 10.5x)")
            report("ratio/InstI-SparF_bs256_vs_FlexGen-SparQ_best_2ssd", 0,
                   f"{sp[-1] / best(fq):.2f}x (paper: 3.11x)")
