"""Paper Fig. 11: accuracy of SparF vs SparQ vs H2O vs local attention
across KV compression ratios.

No external datasets ship offline, so the metric is attention-output
fidelity + next-token agreement against the dense oracle on a small
randomly-initialized model over structured synthetic sequences — the
ordering (SparF ~= SparQ >> H2O > local) is the paper's claim under test.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig, SparFConfig
from repro.core import baselines
from repro.core.offload import decode_attention
from repro.core.paged_kv import init_layer_cache, make_layout, write_prefill
from repro.models.model_zoo import build, forward, init_params, make_inputs
from repro.sharding.policy import NULL

RATIOS = (0.5, 0.25, 0.125, 0.0625)


def _attention_fidelity(report, seed=0):
    """Attention-output cosine similarity per method/ratio on one layer."""
    B, S, KV, G, hd = 4, 256, 4, 2, 64
    H = KV * G
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    # structured K/V: a few heavy directions + noise (gives attention peaks)
    heavy = jax.random.normal(ks[0], (B, 8, KV, hd))
    idx = jax.random.randint(ks[1], (B, S), 0, 8)
    k = (jnp.take_along_axis(heavy, idx[:, :, None, None].repeat(KV, 2)
                             .repeat(hd, 3), axis=1)
         + 0.5 * jax.random.normal(ks[2], (B, S, KV, hd)))
    v = jax.random.normal(ks[3], (B, S, KV, hd))
    q = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, H, hd))
    length = S
    dense = baselines.dense_decode(q, k, v, length)
    acc = jnp.cumsum(jnp.ones((B, KV, S)), -1) * 0.0  # placeholder h2o accum
    # h2o accumulated scores ~ true attention mass (oracle-style)
    qg = q.reshape(B, KV, G, hd)
    w = jax.nn.softmax(jnp.einsum("bkgh,bskh->bkgs", qg, k)
                       / np.sqrt(hd), -1)
    acc = jnp.sum(w, axis=2)

    def cos(a, b):
        num = jnp.sum(a * b)
        return float(num / (jnp.linalg.norm(a) * jnp.linalg.norm(b)))

    for ratio in RATIOS:
        keep = max(4, int(S * ratio))
        r = max(2, int(hd * ratio * 2))
        cfg = build("minitron-8b", smoke=True).replace(
            n_heads=H, n_kv_heads=KV, d_model=H * hd,
            sparf=SparFConfig(rank_r=r, top_k=keep, page_tokens=16))
        layout = make_layout(cfg, S, 1)
        cache = write_prefill(layout, init_layer_cache(layout, B,
                                                       jnp.float32),
                              k, v, lengths=length)
        outs = {
            "sparf": decode_attention(cfg, NULL, layout, q, cache, length,
                                      impl="insti_sparf"),
            "sparq": baselines.sparq_decode(q, k, v, length, r=r, keep=keep,
                                            v_mean=jnp.mean(v, 1)),
            "h2o": baselines.h2o_decode(q, k, v, length, keep, acc),
            "local": baselines.local_decode(q, k, v, length, keep),
        }
        for name, out in outs.items():
            report(f"accuracy/fidelity/{name}/ratio_{ratio}", 0,
                   f"cos={cos(out, dense):.4f}")


def _next_token_agreement(report, seed=0):
    """End-to-end: next-token top-1 agreement with dense decoding on a
    small model."""
    cfg0 = build("minitron-8b", smoke=True).replace(
        max_seq=160, dtype="float32")
    key = jax.random.PRNGKey(seed)
    params = init_params(cfg0, key)
    B, S = 4, 128
    batch = make_inputs(cfg0, ShapeConfig("t", S, B, "prefill"), key)
    layout = make_layout(cfg0, cfg0.max_seq, 1)

    def run(impl, scfg, feed):
        """Teacher-forced decode: both systems consume the same (dense)
        token stream; agreement measures per-step argmax decisions without
        compounding divergence."""
        cfg = cfg0.replace(attention_impl=impl, sparf=scfg)
        _, _, cache = forward(cfg, NULL, params, batch, "prefill",
                              layout=layout, length=S)
        preds = []
        for t in range(16):
            tok = feed[:, t:t + 1]
            logits, _, cache = forward(cfg, NULL, params, {"token": tok},
                                       "decode", cache=cache, layout=layout)
            preds.append(np.asarray(
                jnp.argmax(logits[:, -1], -1).astype(jnp.int32)))
        return np.stack(preds, 1)

    feed = np.asarray(jax.random.randint(jax.random.PRNGKey(7), (B, 17), 0,
                                         cfg0.vocab_size, jnp.int32))
    base = run("insti_dense", cfg0.sparf, feed)
    for ratio in (0.25, 0.125):
        scfg = SparFConfig.for_ratio(S, ratio, cfg0.head_dim, page_tokens=8)
        got = run("insti_sparf", scfg, feed)
        agree = float((got == base).mean())
        report(f"accuracy/agreement/sparf/ratio_{ratio}", 0,
               f"top1_agree={agree:.3f}")


def run(report):
    _attention_fidelity(report)
    _next_token_agreement(report)
