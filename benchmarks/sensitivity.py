"""Paper Fig. 17b: throughput vs KV compression ratio (1 and 2 CSDs)."""
from __future__ import annotations

import dataclasses

from benchmarks.hwmodel import LM, SYSTEMS, throughput, with_drives


def run(report):
    lm = LM()
    for nd in (1, 2):
        for ratio in (1.0, 0.5, 0.25, 0.125, 0.0625):
            sys = dataclasses.replace(
                with_drives(SYSTEMS["InstI-SparF"], nd), sparsity=ratio)
            t = throughput(sys, lm, 256)
            report(f"sensitivity/{nd}csd/ratio_{ratio}", 1e6 / t,
                   f"{t:.2f} tok/s")
