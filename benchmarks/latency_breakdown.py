"""Paper Fig. 14/15: decode-phase latency breakdown (KV access share) for
FlexGen / InstI / InstI-2, dense and 1/8-sparse, at bs in {4, 64, 256}."""
from __future__ import annotations

from benchmarks.hwmodel import LM, SYSTEMS, decode_step_time, with_drives


def run(report):
    lm = LM()
    ctx = lm.seq_in + lm.seq_out // 2
    cases = {
        "FlexGen": SYSTEMS["FlexGen"],
        "InstI": SYSTEMS["InstI-Dense"],
        "InstI-2": with_drives(SYSTEMS["InstI-Dense"], 2),
        "FlexGen-SparQ": SYSTEMS["FlexGen-SparQ"],
        "InstI-SparF": SYSTEMS["InstI-SparF"],
        "InstI-SparF-2": with_drives(SYSTEMS["InstI-SparF"], 2),
    }
    for bs in (4, 64, 256):
        for name, sys in cases.items():
            t = decode_step_time(sys, lm, bs, ctx)
            kv_share = t["kv_s"] / (t["kv_s"] + t["weight_s"]
                                    + t["compute_s"] + t["xfer_s"])
            report(f"latency/{name}/bs{bs}", t["total_s"] * 1e6,
                   f"kv_share={kv_share * 100:.1f}%")
    # paper: FlexGen bs=64 dense kv share 98.9% -> InstI 80.7%
    t_fg = decode_step_time(cases["FlexGen"], lm, 64, ctx)
    t_ii = decode_step_time(cases["InstI"], lm, 64, ctx)
    red = 1 - (t_ii["kv_s"] / t_fg["kv_s"])
    report("latency/kv_access_reduction_dense_bs64", 0,
           f"{red * 100:.1f}% (paper: 88.1-94.0%)")
