"""Paper Fig. 6 / §III-B reproduction: roofline-based operator placement.
The derived column must match the paper's split: only decode-phase
attention (Logit+Attend) is offloaded to the CSD."""
from __future__ import annotations

from repro.core.engine import paper_plan


def run(report):
    expected = {("QKV/O-Proj+FFN", "prefill"): "compute",
                ("Attention", "prefill"): "compute",
                ("QKV/O-Proj+FFN", "decode"): "compute",
                ("Logit+Attend", "decode"): "storage"}
    for row in paper_plan(batch=64):
        key = (row["op"], row["phase"])
        ok = expected[key] == row["placement"]
        report(f"placement/{row['phase']}/{row['op']}",
               row[f"t_{row['placement']}_side_s"] * 1e6,
               f"AI={row['intensity']:.1f} -> {row['placement']} "
               f"({'matches paper' if ok else 'MISMATCH'})")
