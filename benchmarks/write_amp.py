"""Paper §IV-C analysis: access-granularity amplification of the
KV-oriented FTL vs a conventional FTL.

Claims reproduced:
  - per-head vectors are 128 x fp16 = 256 B; 4 KB flash pages mean a
    conventional (token-at-a-time) layout suffers up to 16x read
    amplification — the grouped layout (16 tokens/page) reads at exactly
    page granularity (1x).
  - decode-time appends: one token per step written at 256 B would cost a
    4 KB page program each (16x write amplification, worse with block-
    level erase); the group buffer batches 16 tokens -> 1x page programs,
    and head-major block packing reaches block-granular erase units.

On TPU the same arithmetic governs DMA efficiency: sub-(8,128)-tile reads
waste HBM bandwidth by the identical ratio (DESIGN.md §2).
"""
from __future__ import annotations

PAGE_BYTES = 4096
HEAD_VEC_BYTES = 128 * 2          # head_dim 128, fp16
TOKENS_PER_PAGE = PAGE_BYTES // HEAD_VEC_BYTES
BLOCK_PAGES = 256                 # pages per erase block


def read_amplification(vectors_per_access: int) -> float:
    """Bytes fetched / bytes needed when reading `vectors_per_access`
    random token vectors of one head."""
    needed = vectors_per_access * HEAD_VEC_BYTES
    fetched = vectors_per_access * PAGE_BYTES      # one page per vector
    return fetched / needed


def grouped_read_amplification(group_sparsity_step1: float = 0.5) -> float:
    """Dual-step loading: pages are fetched whole but each carries ~half
    useful tokens in step 1 (paper: 'about half of the sparsity' retained
    at page granularity)."""
    return 1.0 / group_sparsity_step1


def write_amplification_ungrouped() -> float:
    return PAGE_BYTES / HEAD_VEC_BYTES             # page program per token


def write_amplification_grouped() -> float:
    return 1.0                                     # buffer 16 -> 1 program


def run(report):
    report("write_amp/conventional_read", 0,
           f"{read_amplification(1):.0f}x (paper: up to 16x)")
    report("write_amp/grouped_read_step1", 0,
           f"{grouped_read_amplification():.0f}x over-fetch, filtered "
           f"in-buffer (paper: ~half sparsity in step 1)")
    report("write_amp/ungrouped_append", 0,
           f"{write_amplification_ungrouped():.0f}x page programs")
    report("write_amp/grouped_append", 0,
           f"{write_amplification_grouped():.0f}x (group buffer, "
           f"block-packed: {BLOCK_PAGES} pages/erase)")
