"""Analytic hardware model calibrated to the paper's testbed (§V-§VI).

The paper's end-to-end throughput numbers are functions of link/medium
bandwidths (PCIe, SSD, CSD flash channels) that do not exist in this
container, so each paper figure is reproduced from this calibrated model —
the same roofline-style accounting the paper itself uses (Fig. 6) — while
the TPU build reports HLO-derived rooflines (benchmarks/roofline.py).

Calibration targets (paper §VI): InstI-SparF/FlexGen <= 11.1x,
InstI-Dense/FlexGen ~ 6.85x @bs64, SparF/Dense ~ 2.08x @bs256,
InstI bs256 ~ DeepSpeed best +4.6%, DeepSpeed cliff at bs32,
FlexGen OOM at bs128, 20-CSD scaling 8.99x/7.29x.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

# --- paper testbed constants -------------------------------------------------
GPU_TFLOPS = 38.7e12          # A6000 fp16 (paper Fig. 6 roofline)
GPU_VRAM_BW = 768e9
GPU_VRAM = 48e9
HOST_PCIE_BW = 11e9           # effective host<->GPU (pinned copies, no overlap)
HOST_DRAM_EFF = 45e9          # DRAM usable for KV (weights copy + OS resident)
SSD_EXT_BW = 5.5e9            # 980pro sequential read
SSD_FS_OVERHEAD = 0.30        # FS + bounce buffer + double copy (FlexGen path)
SSD_SWAP_EFF = 0.35           # kernel swapping efficiency (DeepSpeed cliff)
CSD_INT_BW = 11.2e9           # aggregated flash-channel bw (paper VI-C)
CSD_FLOPS = 0.44e12           # Zynq7045 DSPs @285MHz
P2P_BW = 12e9                 # GPU<->CSD P2P (Gen3 x4 + protocol)
SPARSE_READ_EFF = 0.55        # random page reads vs sequential (dual-step)
HOST_STEP_OVERHEAD = 8e-3     # host-FS/software per decode step (FlexGen/DS)
CSD_STEP_OVERHEAD = 1e-3      # NVMe command + P2P doorbell per step


@dataclass(frozen=True)
class LM:
    n_layers: int = 40
    d: int = 5120
    n_heads: int = 40
    params: float = 13e9
    seq_in: int = 1024
    seq_out: int = 1024


@dataclass(frozen=True)
class System:
    name: str
    kv_medium: str            # vram | host | ssd | csd
    attn_on: str              # gpu | csd
    sparsity: float = 1.0     # KV compression ratio (1 = dense)
    n_drives: int = 1
    p2p: bool = False


def kv_bytes_per_step(lm: LM, batch: int, ctx: int) -> float:
    return 2 * 2 * batch * ctx * lm.d * lm.n_layers     # K+V fp16


def sparse_bytes_factor(sparsity: float, head_dim: int = 128) -> float:
    """SparF/SparQ bytes actually touched per step, as a fraction of the
    dense K+V traffic: step 1 reads r/hd of the K cache (embedding-indexed
    copy); step 2 reads ratio x (K+V) with ~1.5x page over-fetch
    (dual-step keeps ~half sparsity in step 1, paper IV-C)."""
    if sparsity >= 1.0:
        return 1.0
    r_frac = min(2 * sparsity, 1.0)            # r ~ 2*ratio*hd (SparQ)
    return 0.5 * r_frac + 1.5 * sparsity


def weight_bytes(lm: LM) -> float:
    return 2 * lm.params


def linear_flops(lm: LM, batch: int) -> float:
    return 2 * lm.params * batch


def attn_flops(lm: LM, batch: int, ctx: int) -> float:
    return 4 * batch * ctx * lm.d * lm.n_layers


def kv_path_bw(sys: System, kv_resident: float) -> float:
    if sys.kv_medium == "vram":
        return GPU_VRAM_BW
    if sys.kv_medium == "host":
        if kv_resident > HOST_DRAM_EFF:        # DeepSpeed swap cliff
            return SSD_EXT_BW * SSD_FS_OVERHEAD * SSD_SWAP_EFF
        return HOST_PCIE_BW
    if sys.kv_medium == "ssd":
        # FlexGen: SSD -> host FS -> GPU; extra drives don't help (paper 13)
        return SSD_EXT_BW * SSD_FS_OVERHEAD
    if sys.kv_medium == "csd":
        return CSD_INT_BW * effective_drives(sys)
    raise ValueError(sys.kv_medium)


def effective_drives(sys: System) -> float:
    """Multi-CSD parallel efficiency. The paper measures sub-linear scaling
    (8.99x dense / 7.29x sparse at 20 CSDs, Fig. 17a) from host-fabric
    P2P serialization and head-level load imbalance; we calibrate a single
    efficiency exponent to those two points rather than model the PCIe
    switch fabric."""
    exp = 0.73 if sys.sparsity >= 1.0 else 0.66
    return sys.n_drives ** exp


def decode_step_time(sys: System, lm: LM, batch: int, ctx: int) -> dict:
    """{total_s, weight_s, kv_s, compute_s, xfer_s, host_s}."""
    w_t = weight_bytes(lm) / GPU_VRAM_BW
    lin_t = linear_flops(lm, batch) / GPU_TFLOPS
    kv_dense = kv_bytes_per_step(lm, batch, ctx)
    kv = kv_dense * sparse_bytes_factor(sys.sparsity)
    bw = kv_path_bw(sys, kv_dense)
    eff_bw = bw
    if sys.sparsity < 1.0 and sys.kv_medium == "csd":
        eff_bw = bw * SPARSE_READ_EFF          # random flash page reads
    # the engine falls back to dense streaming if sparsity wouldn't help
    kv_t = min(kv / eff_bw, kv_dense / bw)
    if sys.attn_on == "csd":
        a_t = (attn_flops(lm, batch, ctx) * min(sys.sparsity * 2, 1.0)
               / (CSD_FLOPS * effective_drives(sys)))
        x_t = 4 * batch * lm.d * lm.n_layers * 2 / P2P_BW
        host_t = CSD_STEP_OVERHEAD
        gpu_t = w_t + lin_t
        total = max(gpu_t, max(kv_t, a_t) + x_t) + host_t
    else:
        a_t = attn_flops(lm, batch, ctx) * sys.sparsity / GPU_TFLOPS
        x_t = 0.0
        host_t = 0.0 if sys.kv_medium == "vram" else HOST_STEP_OVERHEAD
        total = w_t + lin_t + kv_t + a_t + host_t
    return {"total_s": total, "weight_s": w_t, "kv_s": kv_t,
            "compute_s": lin_t + a_t, "xfer_s": x_t, "host_s": host_t}


def vram_ok(sys: System, lm: LM, batch: int, ctx: int) -> bool:
    """InstI's layer-wise prefill pipeline needs only one layer of KV in
    VRAM; host/SSD offloaders buffer a large prefill working set (FlexGen
    OOMs at bs=128, paper VI-C)."""
    act = 2 * batch * lm.seq_in * lm.d * 4
    if sys.attn_on == "csd":
        kv_in_vram = kv_bytes_per_step(lm, batch, lm.seq_in) / lm.n_layers
    else:
        kv_in_vram = kv_bytes_per_step(lm, batch, lm.seq_in) * 0.25
    return weight_bytes(lm) + act + kv_in_vram < GPU_VRAM


def throughput(sys: System, lm: LM, batch: int) -> float:
    if not vram_ok(sys, lm, batch, lm.seq_in):
        return 0.0
    total = 0.0
    steps = 8
    for i in range(steps):
        ctx = lm.seq_in + (i + 1) * lm.seq_out // steps
        total += decode_step_time(sys, lm, batch, ctx)["total_s"]
    return batch / (total / steps)


SYSTEMS = {
    "DeepSpeed": System("DeepSpeed", "host", "gpu"),
    "FlexGen": System("FlexGen", "ssd", "gpu"),
    "FlexGen-SparQ": System("FlexGen-SparQ", "ssd", "gpu", sparsity=1 / 8),
    "InstI-Dense": System("InstI-Dense", "csd", "csd", p2p=True),
    "InstI-SparF": System("InstI-SparF", "csd", "csd", sparsity=1 / 8,
                          p2p=True),
}


def with_drives(sys: System, n: int) -> System:
    return replace(sys, n_drives=n)
