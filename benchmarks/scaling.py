"""Paper Fig. 17a: throughput scaling with the number of CSDs (1..20) at
bs=256, dense and 1/8-sparse — head-level parallelism across the array."""
from __future__ import annotations

from benchmarks.hwmodel import LM, SYSTEMS, throughput, with_drives


def run(report):
    lm = LM()
    base_d = throughput(with_drives(SYSTEMS["InstI-Dense"], 1), lm, 256)
    base_s = throughput(with_drives(SYSTEMS["InstI-SparF"], 1), lm, 256)
    for n in (1, 2, 4, 8, 12, 16, 20):
        d = throughput(with_drives(SYSTEMS["InstI-Dense"], n), lm, 256)
        s = throughput(with_drives(SYSTEMS["InstI-SparF"], n), lm, 256)
        report(f"scaling/dense/{n}csd", 1e6 / d, f"{d / base_d:.2f}x")
        report(f"scaling/sparf/{n}csd", 1e6 / s, f"{s / base_s:.2f}x")
    d20 = throughput(with_drives(SYSTEMS["InstI-Dense"], 20), lm, 256)
    s20 = throughput(with_drives(SYSTEMS["InstI-SparF"], 20), lm, 256)
    report("scaling/dense_20csd_speedup", 0,
           f"{d20 / base_d:.2f}x (paper: 8.99x)")
    report("scaling/sparf_20csd_speedup", 0,
           f"{s20 / base_s:.2f}x (paper: 7.29x)")
