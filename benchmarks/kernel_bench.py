"""Kernel micro-harness: wall-time per call for each Pallas kernel
(interpret mode on CPU — structural harness; real numbers come from TPU)
and the pure-jnp reference for comparison."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, *args, n=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6


def run(report):
    key = jax.random.PRNGKey(0)
    B, H, S, hd = 1, 2, 256, 64
    q = jax.random.normal(key, (B, H, S, hd), jnp.float32)
    k = jax.random.normal(key, (B, H, S, hd), jnp.float32)
    v = jax.random.normal(key, (B, H, S, hd), jnp.float32)
    report("kernel/flash_attention_interp",
           _time(lambda *a: ops.flash_attention(*a), q, k, v),
           "vs_ref_us=%.0f" % _time(
               lambda *a: jax.jit(ref.flash_attention)(*a), q, k, v))

    KV, G, P, page = 2, 2, 16, 16
    kp = jax.random.normal(key, (B, KV, P, page, hd), jnp.float32)
    vp = jax.random.normal(key, (B, KV, P, page, hd), jnp.float32)
    qd = jax.random.normal(key, (B, KV, G, hd), jnp.float32)
    bt = jnp.broadcast_to(jnp.arange(P), (B, KV, P)).astype(jnp.int32)
    report("kernel/paged_attention_interp",
           _time(lambda *a: ops.paged_attention(*a), qd, kp, vp, bt, 200),
           "vs_ref_us=%.0f" % _time(
               lambda *a: jax.jit(ref.paged_attention)(*a), qd, kp, vp, bt,
               200))

    ke = kp.reshape(B, KV, P * page, hd).swapaxes(-1, -2)
    vs = jnp.sum(vp.reshape(B, KV, P * page, hd), 2)
    report("kernel/sparf_attention_interp",
           _time(lambda *a: ops.sparf_attention(*a, rank_r=16, top_k=32),
                 qd, kp, vp, ke, bt, vs, 200), "two-kernel pipeline")

    T, D, N = 256, 32, 16
    ab = jax.random.uniform(key, (B, T, D, N), minval=0.5, maxval=0.99)
    bx = jax.random.normal(key, (B, T, D, N)) * 0.1
    ct = jax.random.normal(key, (B, T, N))
    report("kernel/mamba_scan_interp",
           _time(lambda *a: ops.mamba_scan(*a), ab, bx, ct),
           "vs_ref_us=%.0f" % _time(
               lambda *a: jax.jit(lambda x, y, z: ref.mamba_scan(x, y, z)[0]
                                  )(*a), ab, bx, ct))
