"""Benchmark driver — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module names")
    args = ap.parse_args()

    from benchmarks import (accuracy, kernel_bench, latency_breakdown,
                            placement, roofline, scaling, sensitivity,
                            throughput, write_amp)
    modules = {
        "placement": placement,          # Fig. 6 / §III-B operator split
        "write_amp": write_amp,          # §IV-C granularity analysis
        "throughput": throughput,        # Fig. 12/13
        "latency_breakdown": latency_breakdown,   # Fig. 14/15
        "scaling": scaling,              # Fig. 17a
        "sensitivity": sensitivity,      # Fig. 17b
        "accuracy": accuracy,            # Fig. 11
        "kernel_bench": kernel_bench,
        "roofline": roofline,            # §Roofline (from dry-run JSONs)
    }
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")

    def report(name: str, us: float, derived: str = ""):
        print(f"{name},{us:.2f},{derived}")
        sys.stdout.flush()

    for name, mod in modules.items():
        if only and name not in only:
            continue
        try:
            mod.run(report)
        except Exception as e:  # keep the harness going, surface the error
            report(f"{name}/ERROR", 0, repr(e))


if __name__ == "__main__":
    main()
