"""Roofline analysis from the dry-run artifacts (§Roofline).

Per (arch x shape x mesh) JSON record (written by launch/dryrun.py):
  compute term    = HLO_FLOPs_per_device / peak_FLOPs
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / link_bw

(cost_analysis on the SPMD-partitioned module is per-device, as are the
shard shapes in the optimized HLO, so no further division by chip count.)

Also reports MODEL_FLOPS = 6ND (train) / 2·N_active·B (decode) per device,
the useful-compute ratio, the dominant term, and a roofline fraction =
useful_time_of_dominant_resource / achieved_time_of_dominant_resource.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

PEAK_FLOPS = 197e12          # TPU v5e bf16
HBM_BW = 819e9
LINK_BW = 50e9               # ICI per link

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_records(mesh: str = "16x16", variants: bool = False) -> List[dict]:
    recs = []
    if not os.path.isdir(DRYRUN_DIR):
        return recs
    for f in sorted(os.listdir(DRYRUN_DIR)):
        if not f.endswith(".json"):
            continue
        if not variants and "_opt" in f:
            continue                    # §Perf iteration variants
        with open(os.path.join(DRYRUN_DIR, f)) as fh:
            r = json.load(fh)
        r["file"] = f
        if r.get("mesh") == mesh:
            recs.append(r)
    return recs


def _tokens_per_step(shape: str, rec: dict) -> float:
    from repro.configs.base import SHAPES
    s = SHAPES[shape]
    if s.mode == "decode":
        return s.global_batch                   # one token per row
    return s.global_batch * s.seq_len


def terms(rec: dict) -> dict:
    """Three-term roofline per device.

    Raw terms come from the probe-extrapolated HLO costs (scan bodies are
    otherwise counted once; see launch/dryrun.py). Two documented artifacts
    of the CPU host backend make the raw memory term an UPPER BOUND:
      (a) bf16 dot operands are converted to f32 (no native bf16 matmul on
          CPU) — counted in convert_bytes_total; native on TPU,
      (b) XLA gather/scatter cost counts the FULL operand, so the sparse
          page gathers (which on TPU are page-granular DMAs — exactly what
          kernels/sparf_decode.py issues) are charged as dense reads.
    The ADJUSTED memory term therefore uses the analytic minimum HBM
    traffic (weights + touched KV/state + activation spill) — the number a
    TPU DMA engine executing our Pallas kernels would move.

    roofline_fraction = ideal_time / adjusted_step_time, where
      ideal = max(MODEL_FLOPS/peak, min_bytes/HBM)  (the workload's wall)
      adjusted step = max(measured_flops/peak, min_bytes/HBM, coll/link).
    It penalizes excess compute (remat, MoE capacity padding) and
    collectives; raw_fraction additionally charges the raw memory term.
    """
    flops = max(rec.get("flops_total", rec["flops"]), 0.0)
    byts = max(rec.get("bytes_total", rec["bytes_accessed"]), 0.0)
    coll = max(rec.get("collective_bytes_total",
                       rec["collective_bytes"].get("total", 0)), 0)
    t_comp = flops / PEAK_FLOPS
    t_mem_raw = byts / HBM_BW
    min_bytes = _min_bytes_per_device(rec)
    t_mem_adj = min_bytes / HBM_BW
    t_coll = coll / LINK_BW
    n_dev = rec["n_devices"]
    tokens = _tokens_per_step(rec["shape"], rec)
    model_flops_dev = rec["model_flops_per_token"] / 3 * (
        3 if rec["shape"].startswith("train") else 1)  # 6ND train, 2ND fwd
    model_flops_dev = model_flops_dev * tokens / n_dev
    useful_ratio = model_flops_dev / max(flops, 1e-9)
    ideal = max(model_flops_dev / PEAK_FLOPS, t_mem_adj)
    step_adj = max(t_comp, t_mem_adj, t_coll)
    step_raw = max(t_comp, t_mem_raw, t_coll)
    dominant = max((("compute", t_comp), ("memory", t_mem_adj),
                    ("collective", t_coll)), key=lambda kv: kv[1])
    return {"t_compute_s": t_comp, "t_memory_s": t_mem_raw,
            "t_memory_adj_s": t_mem_adj,
            "t_collective_s": t_coll, "dominant": dominant[0],
            "step_est_s": step_adj,
            "model_flops_per_device": model_flops_dev,
            "useful_flop_ratio": min(useful_ratio, 10.0),
            "roofline_fraction": min(ideal / max(step_adj, 1e-12), 1.0),
            "raw_fraction": min(ideal / max(step_raw, 1e-12), 1.0)}


def _min_bytes_per_device(rec: dict) -> float:
    """Minimum HBM traffic per device per step.

    Parameters shard over `model` (16) except grid-EP expert weights
    (data x model = n_dev); weights are re-read every microbatch; train
    touches them 3x (fwd, bwd, optimizer r/w amortized)."""
    from repro.configs.base import SHAPES, get_arch
    cfg = get_arch(rec["arch"])
    shape = SHAPES[rec["shape"]]
    n_dev = rec["n_devices"]
    tp = 16
    n_moe = sum(1 for i in range(cfg.n_layers) if cfg.is_moe_layer(i))
    expert_b = n_moe * cfg.n_experts * 3 * cfg.d_model * cfg.d_ff * 2
    dense_b = rec["param_count"] * 2 - expert_b
    grid = expert_b / tp > 8 << 30
    p_dev = dense_b / tp + expert_b / (n_dev if grid else tp)
    act_dev = (shape.global_batch * shape.seq_len * cfg.d_model * 2
               / max(n_dev // tp, 1))
    if shape.mode == "train":
        n_mb = max(cfg.num_microbatches, 1)
        return p_dev * 3 * n_mb + 4 * act_dev * cfg.n_layers / 8
    if shape.mode == "prefill":
        return p_dev + 2 * act_dev * cfg.n_layers / 8
    # decode: params (active experts only) + touched KV/state
    active_frac = rec["active_param_count"] / max(rec["param_count"], 1)
    kv_heads = max(cfg.n_kv_heads, 1)
    kv_bytes = (2 * cfg.n_layers * shape.global_batch * shape.seq_len
                * kv_heads * (cfg.head_dim or 0) * 2)
    if cfg.attention_impl == "insti_sparf" and cfg.n_kv_heads:
        ratio = min(1.0, cfg.sparf.top_k / shape.seq_len
                    + cfg.sparf.rank_r / max(cfg.head_dim, 1))
        kv_bytes *= ratio
    # at decode every hot expert's weights are touched once per step
    p_dec = dense_b / tp + expert_b / (n_dev if grid else tp)
    return p_dec + kv_bytes / n_dev


def fmt_row(rec: dict) -> str:
    t = terms(rec)
    return ("| {arch} | {shape} | {impl} | {tc:.2e} | {tm:.2e} | {ta:.2e} "
            "| {tl:.2e} | {dom} | {ur:.2f} | {rf:.1%} | {rr:.1%} |").format(
        arch=rec["arch"], shape=rec["shape"], impl=rec["impl"],
        tc=t["t_compute_s"], tm=t["t_memory_s"], ta=t["t_memory_adj_s"],
        tl=t["t_collective_s"], dom=t["dominant"],
        ur=t["useful_flop_ratio"], rf=t["roofline_fraction"],
        rr=t["raw_fraction"])


HEADER = ("| arch | shape | impl | compute s | memory s (raw) "
          "| memory s (adj) | collective s | bottleneck "
          "| useful-FLOP ratio | roofline frac (adj) | raw frac |\n"
          "|---|---|---|---|---|---|---|---|---|---|---|")


def run(report):
    recs = load_records()
    for rec in recs:
        t = terms(rec)
        report(f"roofline/{rec['arch']}/{rec['shape']}",
               t["step_est_s"] * 1e6,
               f"{t['dominant']}-bound frac={t['roofline_fraction']:.2f}")


def main():
    recs = load_records()
    print(HEADER)
    for rec in recs:
        print(fmt_row(rec))


if __name__ == "__main__":
    main()
