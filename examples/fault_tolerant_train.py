"""Fault-tolerant training demo: train, checkpoint every k steps, simulate
a crash, auto-resume from the latest checkpoint, and continue bit-exact.
Run twice to see restart behaviour persist across processes.

    PYTHONPATH=src python examples/fault_tolerant_train.py
"""
import os
import tempfile

import jax
import numpy as np

from repro.models.model_zoo import build, init_params
from repro.runtime import checkpoint as ckpt
from repro.runtime.data import DataConfig, batch_at
from repro.runtime.elastic import StepWatchdog
from repro.runtime.optimizer import OptConfig
from repro.runtime.train_state import init_train_state, make_train_step
from repro.sharding.policy import NULL


def main():
    cfg = build("starcoder2-15b", smoke=True)
    key = jax.random.PRNGKey(0)
    oc = OptConfig(lr=1e-3, warmup_steps=5, total_steps=100)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
    ckpt_dir = os.path.join(tempfile.gettempdir(), "repro_ft_demo")
    step_fn = jax.jit(make_train_step(cfg, NULL, oc))
    watchdog = StepWatchdog()

    def fresh_state():
        return init_train_state(cfg, init_params(cfg, key), oc)

    # resume if a checkpoint exists (stateless data: no replay/skip)
    last = ckpt.latest_step(ckpt_dir)
    state = fresh_state()
    if last is not None:
        state = ckpt.restore(ckpt_dir, last, state)
        print(f"resumed from step {last}")
    start = int(state["step"])

    losses = []
    for i in range(start, start + 12):
        watchdog.start()
        state, metrics = step_fn(state, batch_at(dc, i))
        straggled = watchdog.stop()
        losses.append(float(metrics["loss"]))
        if i % 4 == 3:
            path = ckpt.save(ckpt_dir, int(state["step"]), state, keep=2)
            print(f"step {i}: loss={losses[-1]:.3f} checkpointed -> {path}"
                  + (" [straggler detected]" if straggled else ""))
        if i == start + 6 and last is None:
            print("simulating crash at step", i)
            break
    else:
        print("run complete; final loss", losses[-1])
        return

    # --- crash recovery within the same process ---
    last = ckpt.latest_step(ckpt_dir)
    state2 = ckpt.restore(ckpt_dir, last, fresh_state())
    print(f"recovered at step {last}; continuing")
    for i in range(int(state2["step"]), start + 12):
        state2, metrics = step_fn(state2, batch_at(dc, i))
    print("final loss after recovery:", float(metrics["loss"]))


if __name__ == "__main__":
    main()
