"""Quickstart: build an assigned architecture at smoke scale, train a few
steps on synthetic data, then serve it with SparF attention offloading.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig
from repro.models.model_zoo import build, init_params, make_inputs
from repro.runtime.data import DataConfig, batch_at
from repro.runtime.optimizer import OptConfig
from repro.runtime.train_state import init_train_state, make_train_step
from repro.serving.session import Session
from repro.sharding.policy import NULL


def main():
    cfg = build("glm4-9b", smoke=True).replace(max_seq=128)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    print(f"arch={cfg.name} params={sum(x.size for x in jax.tree.leaves(params)):,}")

    # --- train a few steps ---
    oc = OptConfig(lr=3e-3, warmup_steps=5, total_steps=50)
    state = init_train_state(cfg, params, oc)
    step = jax.jit(make_train_step(cfg, NULL, oc))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
    for i in range(10):
        state, metrics = step(state, batch_at(dc, i))
        if i % 3 == 0:
            print(f"step {i}: loss={float(metrics['loss']):.3f} "
                  f"gnorm={float(metrics['grad_norm']):.3f}")

    # --- serve with the in-storage SparF path ---
    sess = Session(cfg, state["params"], max_seq=128)
    prompt = make_inputs(cfg, ShapeConfig("p", 32, 4, "prefill"), key)
    out = sess.generate(prompt, 16)
    print("generated token ids:\n", out)


if __name__ == "__main__":
    main()
