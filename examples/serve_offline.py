"""End-to-end offline serving driver (the paper's scenario): a ~100M-param
model, batched uniform-length requests, prefill 512 + decode 128, with the
SparF in-storage attention path vs the dense and FlexGen-like baselines —
reports tokens/s for each.

    PYTHONPATH=src python examples/serve_offline.py [--tokens 64]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig, SparFConfig
from repro.models.model_zoo import build, init_params, make_inputs
from repro.serving.session import Session


def run_system(cfg, params, batch, n_tokens, impl):
    cfg = cfg.replace(attention_impl=impl)
    sess = Session(cfg, params, max_seq=1024)
    t0 = time.perf_counter()
    sess.prefill(batch)
    t_prefill = time.perf_counter() - t0
    tok = jnp.zeros((batch["tokens"].shape[0], 1), jnp.int32)
    sess.decode_step(tok)           # compile
    t0 = time.perf_counter()
    for _ in range(n_tokens):
        logits = sess.decode_step(tok)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    tps = batch["tokens"].shape[0] * n_tokens / dt
    return t_prefill, tps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    # ~100M params: 12L x 768 (GPT-2-small-ish), GQA 12/4
    cfg = build("minitron-8b", smoke=True).replace(
        name="demo-100m", n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
        d_ff=3072, vocab_size=32000, max_seq=1024, scan_layers=True,
        sparf=SparFConfig(rank_r=16, top_k=128, page_tokens=16))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n_params/1e6:.0f}M params, batch={args.batch}, "
          f"prefill 512 + decode {args.tokens}")
    batch = make_inputs(cfg, ShapeConfig("p", 512, args.batch, "prefill"),
                        key)
    for impl in ("insti_sparf", "insti_dense"):
        tp, tps = run_system(cfg, params, batch, args.tokens, impl)
        print(f"{impl:14s} prefill {tp:6.2f}s  decode {tps:8.1f} tok/s")


if __name__ == "__main__":
    main()
